//! The parallel LDA trainer: diagonal epochs over a partition plan,
//! executed under a [`Schedule`] mapping the grid onto `W` workers.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::corpus::bow::BagOfWords;
use crate::corpus::shard::{Residency, ShardedBlocks, ShardStore};
use crate::gibbs::counts::LdaCounts;
use crate::gibbs::perplexity;
use crate::gibbs::sampler::Hyper;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::KernelKind;
use crate::obs::metrics::{Family, Phase, Registry};
use crate::obs::trace::{Event, EventKind, Tracer};
use crate::partition::eta::CostMatrix;
use crate::partition::scheme::PartitionMap;
use crate::partition::Plan;
use crate::scheduler::adaptive::{BalanceMode, Measured};
use crate::scheduler::pool::{
    commit_delta, merge_deltas, EngineCache, EpochSpec, EpochTasks, Executor, TaskObs, WorkerPool,
};
use crate::scheduler::schedule::{partition_id, Schedule, ScheduleKind};
use crate::scheduler::shared::SharedRows;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// How diagonal epochs execute (see [`crate::scheduler::pool`]):
///
/// * `Sequential` — in-order on the calling thread; the determinism
///   oracle and the zero-overhead mode for single-core boxes.
/// * `Threaded` — scoped execution: one OS thread *spawned* per busy
///   worker slot per epoch.
/// * `Pooled` — persistent worker pool created once per trainer; epochs
///   are scatter/gathered over channels with per-worker scratch reuse.
///
/// All three produce identical results — task RNG streams are keyed by
/// `(sweep, partition)`, not by worker or interleaving.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Threaded,
    Sequential,
    Pooled,
}

/// Seed salt for the LDA sweep RNG streams: task RNGs are keyed by
/// `(seed ^ LDA_SWEEP_SALT, sweep, partition)`, so LDA and the BoT
/// phases sharing one user seed never share streams. Named (rather than
/// inlined) so fault-injection tests can address exact task coordinates
/// — the `"task"` failpoint key leads with this salted seed (see
/// `crate::util::fault` and `docs/fault_tolerance.md`).
pub(crate) const LDA_SWEEP_SALT: u64 = 0x50AB_71C5;

impl ExecMode {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sequential" | "seq" => Some(Self::Sequential),
            "threaded" | "threads" => Some(Self::Threaded),
            "pooled" | "pool" => Some(Self::Pooled),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Threaded => "threaded",
            Self::Sequential => "sequential",
            Self::Pooled => "pooled",
        }
    }
}

/// How epoch results reconcile into the shared topic totals (see
/// `docs/executor.md` § "Ticketed commit"):
///
/// * `Barrier` — scatter/gather: all deltas are merged after the epoch's
///   full gather barrier (the historical protocol).
/// * `Ticketed` — pipelined: each task's index is its *ticket* (its
///   canonical merge position); a single-threaded committer folds
///   finished deltas in strict ticket order while later tickets are
///   still sampling, so only the epoch's tail folds block. The `barrier`
///   bucket shrinks to one O(K) snapshot republish per epoch; the fold
///   time moves into the `runahead` (overlapped) and `commit` (blocking
///   tail) buckets.
///
/// Both modes commit in the same canonical order against the same
/// epoch-start snapshot, so results are bit-identical — the protocol
/// changes *when* reconciliation work runs, never what it produces.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CommitMode {
    #[default]
    Barrier,
    Ticketed,
}

impl CommitMode {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "barrier" => Some(Self::Barrier),
            "ticketed" | "ticket" => Some(Self::Ticketed),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Barrier => "barrier",
            Self::Ticketed => "ticketed",
        }
    }
}

/// Per-sweep timing/cost telemetry.
#[derive(Clone, Debug, Default)]
pub struct SweepStats {
    /// Wall time of each epoch (seconds).
    pub epoch_secs: Vec<f64>,
    /// Max *per-worker assigned* token count per epoch under the executed
    /// schedule — the epoch's critical path. For the diagonal schedule
    /// this is the max block length (the paper's epoch cost); under
    /// packing it is the max over workers of the *sum* of their task
    /// lists, which can be well below the largest single block.
    pub epoch_max_tokens: Vec<u64>,
    /// Sum of all tasks' token counts (serial-equivalent work).
    pub total_tokens: u64,
    /// Worker count the sweep was scheduled onto.
    pub workers: usize,
    /// Measured per-task sweep nanos: `task_nanos[l][m]` is what
    /// diagonal `l`'s position-`m` partition actually cost — the
    /// telemetry the [`crate::scheduler::adaptive::Measured`] estimator
    /// learns from.
    pub task_nanos: Vec<Vec<u64>>,
    /// Measured per-worker busy nanos per epoch: `worker_nanos[l][w]`
    /// is the sampling wallclock worker slot `w` spent in epoch `l`
    /// (actual time under stealing, not the scheduled hint).
    pub worker_nanos: Vec<Vec<u64>>,
    /// Executor (sampling) seconds summed over epochs — the "sample"
    /// phase bucket.
    pub sample_secs: f64,
    /// Barrier seconds summed over epochs: delta merging under
    /// [`CommitMode::Barrier`]; only the O(K) end-of-epoch snapshot
    /// republish under [`CommitMode::Ticketed`] (the fold time moves to
    /// `runahead_secs`/`commit_secs`).
    pub barrier_secs: f64,
    /// Ticketed commit only: seconds the committer spent folding deltas
    /// *while sampling was still in flight* — run-ahead work hidden in
    /// the shadow of the epoch, costing no wallclock. Always 0 under
    /// `Barrier`.
    pub runahead_secs: f64,
    /// Ticketed commit only: seconds spent folding the epoch's tail
    /// deltas after sampling had drained — the blocking residue the
    /// pipeline could not hide. Always 0 under `Barrier`.
    pub commit_secs: f64,
    /// Update seconds: snapshot upkeep plus any adaptive
    /// observe/re-pack work between epochs and sweeps.
    pub update_secs: f64,
    /// Out-of-core load stalls: seconds the sweep blocked waiting for
    /// diagonal blocks (0 in-core; ≈0 when prefetch fully overlaps
    /// sampling — see [`crate::corpus::shard`]).
    pub io_load_secs: f64,
    /// Out-of-core write-back seconds (dirty `z` arrays after each
    /// epoch's barrier; 0 in-core).
    pub io_write_secs: f64,
    /// Task re-executions after contained worker panics during this
    /// sweep (see [`crate::scheduler::pool::Executor::retries`]). Zero
    /// on a fault-free sweep; retries never change results.
    pub task_retries: u64,
    /// Spill-store IO operations that failed transiently and were
    /// retried during this sweep (reads, write-backs, and prefetches —
    /// see [`crate::corpus::shard::ShardStore`]). Zero in-core.
    pub io_retries: u64,
}

impl SweepStats {
    /// Schedule-aware measured cost: `Σ_l max_w assigned_tokens(w, l)`
    /// (reduces to Eq. 1 under the diagonal schedule).
    pub fn measured_cost(&self) -> u64 {
        self.epoch_max_tokens.iter().sum()
    }

    /// Measured critical path of the sweep in nanos:
    /// `Σ_l max_w busy(l, w)` — the wallclock analogue of Eq. 1, over
    /// what workers actually spent rather than token counts.
    pub fn crit_nanos(&self) -> u64 {
        self.worker_nanos
            .iter()
            .map(|ws| ws.iter().copied().max().unwrap_or(0))
            .sum()
    }

    /// Total measured sampling nanos (the serial-equivalent work).
    pub fn busy_total_nanos(&self) -> u64 {
        self.worker_nanos.iter().flatten().sum()
    }

    /// Per-worker busy nanos summed over the sweep's epochs.
    pub fn worker_busy(&self) -> Vec<u64> {
        let mut busy = vec![0u64; self.workers];
        for ws in &self.worker_nanos {
            for (w, &ns) in ws.iter().enumerate() {
                busy[w] += ns;
            }
        }
        busy
    }

    /// Per-worker idle nanos: time spent waiting at epoch barriers,
    /// `Σ_l (max_w' busy(l, w') − busy(l, w))` — what imbalance costs
    /// each worker.
    pub fn worker_idle(&self) -> Vec<u64> {
        let mut idle = vec![0u64; self.workers];
        for ws in &self.worker_nanos {
            let crit = ws.iter().copied().max().unwrap_or(0);
            for (w, &ns) in ws.iter().enumerate() {
                idle[w] += crit - ns;
            }
        }
        idle
    }

    /// Measured-η: serial-equivalent sampling nanos over `W ×` the
    /// measured critical path — Eq. 2 evaluated on wallclock instead of
    /// token counts. Equals token-η when per-token cost is uniform;
    /// the gap between the two is exactly what cost-aware balancing
    /// (adaptive re-packing, stealing) recovers. Returns 1.0 when
    /// nothing was measured.
    pub fn measured_eta(&self) -> f64 {
        let crit = self.crit_nanos();
        if crit == 0 {
            return 1.0;
        }
        self.busy_total_nanos() as f64 / (self.workers.max(1) as f64 * crit as f64)
    }
}

/// Generate a plan's token blocks diagonal by diagonal under a residency
/// policy, handing each block to `absorb` (count initialization) before
/// the policy decides whether it stays resident — the invariant that
/// keeps spill-mode init peak memory at roughly one diagonal. Shared by
/// [`ParallelLda`] and the BoT trainer's phases; `store_tag` names the
/// temp spill directory.
pub(crate) fn build_blocks(
    map: &PartitionMap,
    p: usize,
    k: usize,
    rng: &mut Rng,
    residency: Residency,
    store_tag: &str,
    mut absorb: impl FnMut(&TokenBlock),
) -> Result<ShardedBlocks> {
    let mut shards = match residency {
        Residency::InCore => ShardedBlocks::in_core(),
        Residency::Spill { budget_bytes } => {
            ShardedBlocks::spill(ShardStore::create_temp(store_tag)?, budget_bytes)
        }
    };
    for l in 0..p {
        let mut diag = Vec::with_capacity(p);
        let mut diag_ids = Vec::with_capacity(p);
        for (m, n) in map.diagonal(l) {
            let b = TokenBlock::from_cells(map.cells(m, n), k, rng);
            absorb(&b);
            diag.push(b);
            diag_ids.push(partition_id(m, n, p));
        }
        shards.push_diagonal(diag, diag_ids)?;
    }
    Ok(shards)
}

/// Parallel partitioned collapsed-Gibbs LDA (Yan et al.'s algorithm over
/// the paper's partition plans), scheduled onto `W` workers.
pub struct ParallelLda {
    pub h: Hyper,
    pub counts: LdaCounts,
    /// Grid size `P` of the partition plan.
    pub p: usize,
    /// Token blocks under the residency policy, diagonal-major:
    /// diagonal `l`'s position-`m` block is partition `(m, (m+l) mod P)`.
    /// In-core they all stay resident; in spill mode at most ~two
    /// diagonals are (see [`crate::corpus::shard::ShardedBlocks`]).
    shards: ShardedBlocks,
    /// The plan's token-cost matrix; schedules are (re)built against it.
    costs: CostMatrix,
    /// Grid → worker mapping executed by [`Self::sweep`].
    schedule: Schedule,
    /// Sampling kernel the executors run (see [`crate::kernel`]).
    kernel: KernelKind,
    /// Load-balancing strategy (see [`crate::scheduler::adaptive`]):
    /// static token-LPT, measured-cost re-packing between sweeps, or
    /// within-epoch work stealing. Result-invariant — only wallclock
    /// changes.
    balance: BalanceMode,
    /// Measured per-partition cost estimator feeding `Adaptive`
    /// re-packing. It observes every sweep's telemetry regardless of
    /// balance mode, so switching to `Adaptive` mid-training starts
    /// warm.
    estimator: Measured,
    /// Commit protocol (barrier gather vs ticketed pipeline). Result-
    /// invariant; see [`CommitMode`].
    commit: CommitMode,
    seed: u64,
    sweeps_done: usize,
    /// Executor state; the persistent worker pool (if `Pooled` mode is
    /// used) lives here for the trainer's lifetime.
    engines: EngineCache,
    /// Double-buffered epoch-start view of `counts.topic`: merged deltas
    /// are applied to both, so no epoch ever clones the topic totals.
    snapshot: Vec<u32>,
    /// Per-task signed topic deltas, zeroed and rewritten each epoch.
    deltas: Vec<Vec<i64>>,
    /// Per-task measured nanos, rewritten each epoch (telemetry scratch).
    task_nanos: Vec<u64>,
    /// Per-worker busy nanos, rewritten each epoch (telemetry scratch).
    worker_nanos: Vec<u64>,
    /// Structured tracer, when attached (`--trace-out`). Strictly
    /// observational — no sampling decision reads it — so tracing on ≡
    /// off bit-for-bit (see `docs/observability.md`).
    tracer: Option<Arc<Tracer>>,
    /// Metrics registry: the single source of truth the per-sweep
    /// `SweepStats` second-buckets and the report `PhaseTimer` are
    /// views over.
    metrics: Registry,
}

impl ParallelLda {
    /// Random-initialize assignments under a partition plan, executed
    /// with the legacy diagonal schedule (`W == plan.p`).
    pub fn init(
        bow: &BagOfWords,
        plan: &Plan,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
    ) -> Self {
        Self::init_scheduled(bow, plan, k, alpha, beta, seed, ScheduleKind::Diagonal, plan.p)
    }

    /// Random-initialize assignments under a partition plan with an
    /// explicit schedule: `kind` maps the `plan.p` grid onto `workers`
    /// worker slots (see [`Schedule::build`] for the compatibility
    /// rules). Token initialization depends only on the plan and seed,
    /// never on the schedule, so any `(kind, workers)` over the same
    /// plan trains to bit-identical counts.
    #[allow(clippy::too_many_arguments)]
    pub fn init_scheduled(
        bow: &BagOfWords,
        plan: &Plan,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
    ) -> Self {
        Self::init_resident(bow, plan, k, alpha, beta, seed, kind, workers, Residency::InCore)
            .expect("in-core init performs no IO")
    }

    /// As [`Self::init_scheduled`], with an explicit [`Residency`]. Under
    /// `Spill` each diagonal's blocks are written to a temp
    /// [`ShardStore`] as they are generated, so init peak memory stays at
    /// roughly one diagonal; training then streams diagonals through RAM
    /// (see [`crate::corpus::shard`]). Residency never changes results:
    /// blocks round-trip bit-exactly and RNG streams are keyed by
    /// `(sweep, partition)`.
    #[allow(clippy::too_many_arguments)]
    pub fn init_resident(
        bow: &BagOfWords,
        plan: &Plan,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
        residency: Residency,
    ) -> Result<Self> {
        let p = plan.p;
        let schedule = Schedule::build(kind, &plan.costs, workers);
        let map = PartitionMap::build(bow, plan);
        let mut rng = Rng::stream(seed, 0x1417);
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        let shards = build_blocks(&map, p, k, &mut rng, residency, "lda", |b| counts.absorb(b))?;
        let workers = schedule.workers;
        Ok(Self {
            h: Hyper::new(k, alpha, beta, bow.num_words()),
            counts,
            p,
            shards,
            costs: plan.costs.clone(),
            engines: EngineCache::new(workers),
            schedule,
            kernel: KernelKind::Dense,
            balance: BalanceMode::Static,
            estimator: Measured::new(p),
            commit: CommitMode::default(),
            seed,
            sweeps_done: 0,
            snapshot: vec![0; k],
            deltas: vec![vec![0i64; k]; p],
            task_nanos: vec![0; p],
            worker_nanos: vec![0; workers],
            tracer: None,
            metrics: Registry::new(),
        })
    }

    /// Rebuild a trainer from a kept spill directory — the crash-safety
    /// path. Every partition's full `(docs, words, z)` state lives in the
    /// store, so the count matrices are reconstructed exactly by
    /// re-absorbing the stored blocks; `sweeps_done` must be the number
    /// of completed sweeps (it keys the task RNG streams), after which
    /// training continues bit-identically to an uninterrupted run.
    #[allow(clippy::too_many_arguments)]
    pub fn resume_spilled(
        bow: &BagOfWords,
        plan: &Plan,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
        dir: &Path,
        sweeps_done: usize,
        residency: Residency,
    ) -> Result<Self> {
        let p = plan.p;
        let schedule = Schedule::build(kind, &plan.costs, workers);
        let map = PartitionMap::build(bow, plan);
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        let store = ShardStore::open(dir)?;
        let expected = sweeps_done as u64;
        let diag_ids = |l: usize| -> Vec<u64> {
            map.diagonal(l).map(|(m, n)| partition_id(m, n, p)).collect()
        };
        let shards = match residency {
            Residency::InCore => {
                let mut shards = ShardedBlocks::in_core();
                for l in 0..p {
                    let ids = diag_ids(l);
                    let mut diag = Vec::with_capacity(ids.len());
                    for &id in &ids {
                        let b = store.read_block_verified(id, expected)?;
                        counts.absorb(&b);
                        diag.push(b);
                    }
                    shards.push_diagonal(diag, ids)?;
                }
                shards // `store` drops here; opened stores keep their files
            }
            Residency::Spill { budget_bytes } => {
                let mut shards = ShardedBlocks::spill(store, budget_bytes);
                for l in 0..p {
                    shards.adopt_diagonal(diag_ids(l), expected, |b| counts.absorb(b))?;
                }
                shards
            }
        };
        let workers = schedule.workers;
        Ok(Self {
            h: Hyper::new(k, alpha, beta, bow.num_words()),
            counts,
            p,
            shards,
            costs: plan.costs.clone(),
            engines: EngineCache::new(workers),
            schedule,
            kernel: KernelKind::Dense,
            balance: BalanceMode::Static,
            estimator: Measured::new(p),
            commit: CommitMode::default(),
            seed,
            sweeps_done,
            snapshot: vec![0; k],
            deltas: vec![vec![0i64; k]; p],
            task_nanos: vec![0; p],
            worker_nanos: vec![0; workers],
            tracer: None,
            metrics: Registry::new(),
        })
    }

    /// Rebuild a trainer by *copying* blocks out of a checkpoint store.
    /// Unlike [`Self::resume_spilled`] — which adopts the directory as
    /// its live spill store — this verified-reads every block (CRC32
    /// checksums plus the `sweeps_done` stamp), re-absorbs the counts,
    /// and builds a fresh block container under `residency` (a new temp
    /// spill store when spilling), leaving the checkpoint untouched for
    /// future resumes. The checkpoint drivers in
    /// `crate::coordinator::checkpoint` resume through this.
    #[allow(clippy::too_many_arguments)]
    pub fn resume_from_store(
        bow: &BagOfWords,
        plan: &Plan,
        k: usize,
        alpha: f32,
        beta: f32,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
        store: &ShardStore,
        sweeps_done: usize,
        residency: Residency,
    ) -> Result<Self> {
        let p = plan.p;
        let schedule = Schedule::build(kind, &plan.costs, workers);
        let map = PartitionMap::build(bow, plan);
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        let expected = sweeps_done as u64;
        let mut shards = match residency {
            Residency::InCore => ShardedBlocks::in_core(),
            Residency::Spill { budget_bytes } => {
                ShardedBlocks::spill(ShardStore::create_temp("lda")?, budget_bytes)
            }
        };
        // Blocks re-spilled while rebuilding must carry the checkpoint's
        // stamp, preserving the at-rest invariant until the next sweep
        // bumps it.
        shards.set_stamp(expected);
        for l in 0..p {
            let ids: Vec<u64> = map.diagonal(l).map(|(m, n)| partition_id(m, n, p)).collect();
            let mut diag = Vec::with_capacity(ids.len());
            for &id in &ids {
                let b = store.read_block_verified(id, expected)?;
                counts.absorb(&b);
                diag.push(b);
            }
            shards.push_diagonal(diag, ids)?;
        }
        let workers = schedule.workers;
        Ok(Self {
            h: Hyper::new(k, alpha, beta, bow.num_words()),
            counts,
            p,
            shards,
            costs: plan.costs.clone(),
            engines: EngineCache::new(workers),
            schedule,
            kernel: KernelKind::Dense,
            balance: BalanceMode::Static,
            estimator: Measured::new(p),
            commit: CommitMode::default(),
            seed,
            sweeps_done,
            snapshot: vec![0; k],
            deltas: vec![vec![0i64; k]; p],
            task_nanos: vec![0; p],
            worker_nanos: vec![0; workers],
            tracer: None,
            metrics: Registry::new(),
        })
    }

    /// Sweeps completed so far. This is the checkpoint coordinate: task
    /// RNG streams for sweep `s` depend only on `(seed, s, partition)`,
    /// never on how the trainer reached sweep `s`.
    pub fn sweeps_done(&self) -> usize {
        self.sweeps_done
    }

    /// The base RNG seed this trainer was initialized with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Export every partition's current `(docs, words, z)` state into
    /// `dst`, stamped with the completed sweep count — the checkpoint
    /// primitive (see `crate::coordinator::checkpoint`). Blocks are
    /// copied from memory, or verified-read from the live spill store
    /// when evicted; the trainer is unchanged. Call between sweeps only
    /// (the at-rest stamp equals `sweeps_done` there).
    pub fn export_blocks(&self, dst: &ShardStore) -> Result<()> {
        self.shards.export_to(dst)?;
        Ok(())
    }

    /// Re-map the same plan onto a different worker count / schedule
    /// kind mid-training. Results are unaffected — RNG streams are keyed
    /// by partition, not by worker — but the executor state (including
    /// any persistent pool) is rebuilt for the new worker count.
    pub fn set_schedule(&mut self, kind: ScheduleKind, workers: usize) {
        self.schedule = Schedule::build(kind, &self.costs, workers);
        self.engines = EngineCache::new(workers);
        self.worker_nanos = vec![0; workers];
        if self.balance == BalanceMode::Adaptive {
            // Fresh packings should chase measured cost immediately, not
            // wait for the next sweep's repack.
            self.estimator.repack(&mut self.schedule, &self.costs);
        }
    }

    /// The schedule executing this trainer's sweeps.
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// Select the sampling kernel for subsequent sweeps. Each kernel's
    /// chain is individually deterministic across executors, schedules,
    /// and worker counts, but different kernels consume RNG differently,
    /// so switching kernels changes the chain (not its stationary
    /// distribution).
    pub fn set_kernel(&mut self, kernel: KernelKind) {
        self.kernel = kernel;
    }

    /// The kernel running this trainer's sweeps.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Select the load-balancing strategy for subsequent sweeps (see
    /// [`crate::scheduler::adaptive`]). Results are unaffected — the
    /// partition-keyed RNG makes any task-to-worker assignment
    /// bit-identical — only which worker runs what, and therefore
    /// wallclock, changes. Switching away from `Adaptive` restores the
    /// token-count packing.
    pub fn set_balance(&mut self, balance: BalanceMode) {
        if self.balance == balance {
            return;
        }
        self.balance = balance;
        match balance {
            // Start from the estimator's best current guess.
            BalanceMode::Adaptive => self.estimator.repack(&mut self.schedule, &self.costs),
            // Back to the pure token packing (assignments are hints
            // under `Steal`, but keep them at the static baseline).
            BalanceMode::Static | BalanceMode::Steal => {
                let costs = &self.costs;
                self.schedule.repack_with(|m, n| costs.get(m, n));
            }
        }
    }

    /// The balance mode governing this trainer's sweeps.
    pub fn balance(&self) -> BalanceMode {
        self.balance
    }

    /// Select the commit protocol for subsequent sweeps (see
    /// [`CommitMode`]). Result-invariant — both protocols fold deltas in
    /// the same canonical order against the same epoch-start snapshot —
    /// so it may be switched mid-training; only where reconciliation
    /// time is spent (and therefore wallclock) changes.
    pub fn set_commit(&mut self, commit: CommitMode) {
        self.commit = commit;
    }

    /// The commit protocol governing this trainer's sweeps.
    pub fn commit(&self) -> CommitMode {
        self.commit
    }

    /// The measured per-partition cost estimator (telemetry-fed; drives
    /// `Adaptive` re-packing).
    pub fn estimator(&self) -> &Measured {
        &self.estimator
    }

    /// Worker slots the current schedule runs on.
    pub fn workers(&self) -> usize {
        self.schedule.workers
    }

    /// Attach (or detach) a structured tracer. Subsequent sweeps emit
    /// per-task spans and coordinator/IO events into its ring buffers
    /// and drain them at each sweep boundary. Tracing is strictly
    /// observational: results are bit-identical with or without it.
    /// The tracer should be sized for [`Self::workers`] lanes.
    pub fn set_tracer(&mut self, tracer: Option<Arc<Tracer>>) {
        self.tracer = tracer;
    }

    /// The attached tracer, if any.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// The trainer's metrics registry — phase wallclock accounts,
    /// fault/balance counters, the per-task duration histogram, and
    /// memory gauges. `SweepStats` second-buckets and the report phase
    /// breakdown are views over this.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// One full Gibbs sweep = `P` diagonal epochs, reconciled under the
    /// configured [`CommitMode`] (gather barrier, or the ticketed
    /// pipelined commit — see [`Self::set_commit`]).
    ///
    /// Epochs dispatch through the [`crate::scheduler::pool::Executor`]
    /// selected by `mode`, each executing its schedule epoch's per-worker
    /// task lists; the topic snapshot is double-buffered and the
    /// per-task delta slots are reused, so the steady-state hot path
    /// performs no per-epoch heap allocation in `Sequential` and
    /// `Pooled` modes.
    pub fn sweep(&mut self, mode: ExecMode) -> SweepStats {
        // Detach the engine cache so the chosen executor can be borrowed
        // mutably alongside `self` (the epoch loops take `&mut self` for
        // counts/shards and `&mut dyn Executor` separately). The
        // placeholder cache is never exercised: `EngineCache::new` builds
        // its pool lazily, so the swap is allocation-free.
        let mut engines = std::mem::replace(&mut self.engines, EngineCache::new(0));
        let stats = self.sweep_with(engines.get(mode));
        self.engines = engines;
        stats
    }

    /// [`Self::sweep`] against an explicit [`Executor`] — the seam the
    /// distributed layer plugs into: `crate::dist::DistExec` implements
    /// [`Executor`] over remote workers, and driving it through this
    /// method reuses the whole sweep loop (scheduling, snapshots,
    /// telemetry, spill IO) unchanged.
    pub fn sweep_with(&mut self, exec: &mut dyn Executor) -> SweepStats {
        let sweep_no = self.sweeps_done;
        let steal = self.balance.is_steal();
        let mut stats = SweepStats {
            workers: self.schedule.workers,
            ..SweepStats::default()
        };
        // Phase seconds are accumulated in the registry; the sweep
        // snapshots the accounts here and reports its increments as the
        // `SweepStats` second-buckets below.
        let phases0 = self.metrics.phase_snapshot();
        let sweep_t0 = self.tracer.as_deref().map(Tracer::now);
        // Spill write-backs during this sweep carry the sweep count they
        // complete, so an at-rest store is uniformly stamped and resume
        // can verify it is not mid-sweep.
        self.shards.set_stamp(sweep_no as u64 + 1);
        // Fault-tolerance telemetry baselines: both counters are
        // monotone over the trainer's lifetime; the sweep reports its
        // increments.
        let task_retries0 = exec.retries();
        let io_retries0 = self.shards.io_retries();

        // Bring the persistent snapshot buffer up to date once per sweep
        // (k u32s — cheap); per-epoch it is maintained by the merge below.
        let update_started = Instant::now();
        self.snapshot.copy_from_slice(&self.counts.topic);
        self.metrics
            .add_phase(Family::Word, Phase::Update, update_started.elapsed());

        if self.commit == CommitMode::Ticketed {
            self.ticketed_epochs(exec, &mut stats, sweep_no, steal);
        } else {
            self.barrier_epochs(exec, &mut stats, sweep_no, steal);
        }

        self.sweeps_done += 1;
        // Fold the sweep's telemetry into the estimator regardless of
        // balance mode (O(P) per sweep), so switching to `Adaptive`
        // mid-training repacks from warm measurements; under `Adaptive`
        // also re-pack each diagonal so the next sweep's assignments
        // chase measured cost. Pure assignment motion: results unchanged.
        let update_started = Instant::now();
        self.estimator.observe_sweep(&self.costs, &stats.task_nanos);
        if !steal {
            // Per-worker speed telemetry (measured vs predicted busy
            // time), so adaptive re-packing can account for
            // heterogeneous workers. Under stealing the static
            // assignment is only a hint, so the prediction wouldn't
            // describe what each worker actually ran.
            let predicted = self
                .estimator
                .predicted_worker_loads(&self.schedule, &self.costs);
            self.estimator.observe_workers(&predicted, &stats.worker_nanos);
        }
        if self.balance == BalanceMode::Adaptive {
            self.estimator.repack(&mut self.schedule, &self.costs);
        }
        self.metrics
            .add_phase(Family::Word, Phase::Update, update_started.elapsed());
        stats.task_retries = exec.retries() - task_retries0;
        stats.io_retries = self.shards.io_retries() - io_retries0;

        // The `SweepStats` second-buckets are views over the registry:
        // this sweep's increments of the phase accounts.
        let m = &self.metrics;
        stats.sample_secs = m.delta_secs(&phases0, Family::Word, Phase::Sample);
        stats.barrier_secs = m.delta_secs(&phases0, Family::Word, Phase::Barrier);
        stats.update_secs = m.delta_secs(&phases0, Family::Word, Phase::Update);
        stats.commit_secs = m.delta_secs(&phases0, Family::Word, Phase::Commit);
        stats.runahead_secs = m.delta_secs(&phases0, Family::Word, Phase::Runahead);
        stats.io_load_secs = m.delta_secs(&phases0, Family::Word, Phase::SpillLoad);
        stats.io_write_secs = m.delta_secs(&phases0, Family::Word, Phase::SpillWrite);
        m.sweeps.inc();
        m.tasks
            .add(stats.task_nanos.iter().map(|v| v.len() as u64).sum());
        m.task_retries.add(stats.task_retries);
        m.io_retries.add(stats.io_retries);
        for &ns in stats.task_nanos.iter().flatten() {
            m.task_ns.observe(ns);
        }
        m.observe_eta(Family::Word, stats.busy_total_nanos(), stats.crit_nanos());
        m.resident_bytes.set(self.shards.resident_bytes());
        m.peak_resident_bytes
            .set_max(self.shards.peak_resident_bytes());

        if let Some(tr) = self.tracer.as_deref() {
            let t0 = sweep_t0.unwrap_or(0);
            tr.emit(Event {
                lane: tr.coord_lane(),
                sweep: sweep_no as u32,
                t0_ns: t0,
                dur_ns: tr.now().saturating_sub(t0),
                ..Event::of(EventKind::Sweep)
            });
            if stats.io_retries > 0 {
                tr.emit(Event {
                    lane: tr.io_lane(),
                    sweep: sweep_no as u32,
                    t0_ns: tr.now(),
                    arg: stats.io_retries,
                    ..Event::of(EventKind::IoRetry)
                });
            }
            // Sweep boundary: move this sweep's ring contents to the
            // sink so rings never need more than one sweep of capacity.
            tr.drain();
        }
        // Debug builds (unit + integration test runs) audit the full
        // count/assignment invariant after every sweep, so a kernel
        // count-delta bug fails loudly at the sweep that introduced it
        // instead of surfacing as a perplexity drift much later. The
        // audit needs the whole corpus in RAM, so spill-mode sweeps skip
        // it (the spill ≡ in-core matrix tests cover that path).
        #[cfg(debug_assertions)]
        if self.shards.fully_resident() {
            let blocks = self.shards.resident_blocks();
            if let Err(e) = self.counts.check_consistency(&blocks) {
                panic!(
                    "kernel {} corrupted LDA counts on sweep {sweep_no}: {e}",
                    self.kernel.name()
                );
            }
        }
        stats
    }

    /// The barrier epoch loop of [`Self::sweep`]
    /// ([`CommitMode::Barrier`]): scatter, gather, merge all deltas,
    /// write back.
    fn barrier_epochs(
        &mut self,
        exec: &mut dyn Executor,
        stats: &mut SweepStats,
        sweep_no: usize,
        steal: bool,
    ) {
        let p = self.p;
        let k = self.h.k;
        let spill = self.shards.residency() != Residency::InCore;
        for l in 0..p {
            // Out-of-core: make this diagonal resident (collecting the
            // prefetch the previous epoch overlapped with its sampling),
            // then start loading the next one on the IO thread. Both are
            // no-ops in-core.
            let load_secs = self
                .shards
                .acquire(l)
                .expect("out-of-core: loading a diagonal from the shard store failed");
            self.metrics
                .add_phase_secs(Family::Word, Phase::SpillLoad, load_secs);
            if p > 1 {
                self.shards.prefetch((l + 1) % p);
            }
            self.trace_io(sweep_no, l, EventKind::IoLoad, load_secs, spill);
            let epoch_started = Instant::now();
            let epoch_t0 = self.tracer.as_deref().map(Tracer::now);
            let (diag, ids) = self.shards.diag_parts(l);
            let ep = &self.schedule.epochs[l];
            stats
                .epoch_max_tokens
                .push(ep.max_assigned(|i| diag[i].len() as u64));
            stats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
            let n = diag.len();

            let spec = EpochSpec {
                doc: SharedRows::new(&mut self.counts.doc_topic, k),
                emit: SharedRows::new(&mut self.counts.word_topic, k),
                snapshot: &self.snapshot,
                h: self.h,
                seed: self.seed ^ LDA_SWEEP_SALT,
                sweep: sweep_no,
                kernel: self.kernel,
                obs: TaskObs {
                    trace: self.tracer.as_deref(),
                    epoch: l as u32,
                    family: Family::Word as u8,
                },
            };
            let tasks = EpochTasks {
                blocks: diag,
                ids,
                assign: &ep.assign,
                nanos: &mut self.task_nanos[..n],
                worker_nanos: &mut self.worker_nanos,
                steal,
            };
            exec.run_epoch(&spec, tasks, &mut self.deltas[..n]);
            self.metrics
                .add_phase(Family::Word, Phase::Sample, epoch_started.elapsed());
            stats.task_nanos.push(self.task_nanos[..n].to_vec());
            stats.worker_nanos.push(self.worker_nanos.clone());

            // Barrier: reconcile topic totals into both the authoritative
            // counts and the snapshot buffer for the next epoch.
            let barrier_started = Instant::now();
            merge_deltas(&mut self.counts.topic, &mut self.snapshot, &self.deltas[..n]);
            let barrier_dur = barrier_started.elapsed();
            self.metrics
                .add_phase(Family::Word, Phase::Barrier, barrier_dur);
            stats.epoch_secs.push(epoch_started.elapsed().as_secs_f64());
            if let Some(tr) = self.tracer.as_deref() {
                let bns = barrier_dur.as_nanos() as u64;
                tr.emit(Event {
                    lane: tr.coord_lane(),
                    sweep: sweep_no as u32,
                    epoch: l as u32,
                    t0_ns: tr.now().saturating_sub(bns),
                    dur_ns: bns,
                    ..Event::of(EventKind::Barrier)
                });
                let t0 = epoch_t0.unwrap_or(0);
                tr.emit(Event {
                    lane: tr.coord_lane(),
                    sweep: sweep_no as u32,
                    epoch: l as u32,
                    t0_ns: t0,
                    dur_ns: tr.now().saturating_sub(t0),
                    ..Event::of(EventKind::Epoch)
                });
            }
            // Out-of-core: the barrier sequenced all sampling of this
            // diagonal — write its dirty `z` arrays back and evict.
            let write_secs = self
                .shards
                .release(l)
                .expect("out-of-core: writing a diagonal back to the shard store failed");
            self.metrics
                .add_phase_secs(Family::Word, Phase::SpillWrite, write_secs);
            self.trace_io(sweep_no, l, EventKind::IoWrite, write_secs, spill);
        }
    }

    /// Emit the IO-lane telemetry for one epoch boundary: a load or
    /// write-back span (when any stall was measured) plus, in spill
    /// mode, a prefetch-reservation instant and a resident-bytes
    /// counter sample. No-op without a tracer.
    fn trace_io(&self, sweep_no: usize, l: usize, kind: EventKind, secs: f64, spill: bool) {
        let Some(tr) = self.tracer.as_deref() else {
            return;
        };
        if secs > 0.0 {
            let dur = (secs * 1e9) as u64;
            tr.emit(Event {
                lane: tr.io_lane(),
                sweep: sweep_no as u32,
                epoch: l as u32,
                t0_ns: tr.now().saturating_sub(dur),
                dur_ns: dur,
                ..Event::of(kind)
            });
        }
        if spill {
            tr.emit(Event {
                lane: tr.io_lane(),
                sweep: sweep_no as u32,
                epoch: l as u32,
                t0_ns: tr.now(),
                arg: self.shards.inflight_bytes(),
                ..Event::of(EventKind::Prefetch)
            });
            tr.emit(Event {
                lane: tr.io_lane(),
                sweep: sweep_no as u32,
                epoch: l as u32,
                t0_ns: tr.now(),
                arg: self.shards.resident_bytes(),
                ..Event::of(EventKind::ResidentBytes)
            });
        }
    }

    /// The ticketed epoch loop of [`Self::sweep`]
    /// ([`CommitMode::Ticketed`]): the executor commits each task's
    /// delta into the authoritative topic totals in strict ticket order
    /// *while the epoch's tail is still sampling* (in-flight tasks read
    /// the immutable epoch-start snapshot, whose denominators the
    /// commits must not perturb — see `docs/executor.md`). The gather
    /// barrier shrinks to one O(K) snapshot republish per epoch, and the
    /// spill write-back of the previous diagonal plus the prefetch of
    /// the next both run in the `overlap` hook, in the shadow of
    /// sampling.
    fn ticketed_epochs(
        &mut self,
        exec: &mut dyn Executor,
        stats: &mut SweepStats,
        sweep_no: usize,
        steal: bool,
    ) {
        let p = self.p;
        let k = self.h.k;
        let spill = self.shards.residency() != Residency::InCore;
        for l in 0..p {
            // The previous epoch's overlap hook started loading this
            // diagonal; its write-back of diagonal `l - 1` happens in
            // *this* epoch's hook below.
            let load_secs = self
                .shards
                .acquire(l)
                .expect("out-of-core: loading a diagonal from the shard store failed");
            self.metrics
                .add_phase_secs(Family::Word, Phase::SpillLoad, load_secs);
            self.trace_io(sweep_no, l, EventKind::IoLoad, load_secs, spill);
            let epoch_started = Instant::now();
            let epoch_t0 = self.tracer.as_deref().map(Tracer::now);
            // Detach the diagonal so the overlap hook can schedule IO on
            // the shard container while the executor samples its blocks
            // (the diagonal stays accounted against the spill budget).
            let (mut diag, ids) = self.shards.take_diagonal(l);
            let ep = &self.schedule.epochs[l];
            stats
                .epoch_max_tokens
                .push(ep.max_assigned(|i| diag[i].len() as u64));
            stats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
            let n = diag.len();

            let spec = EpochSpec {
                doc: SharedRows::new(&mut self.counts.doc_topic, k),
                emit: SharedRows::new(&mut self.counts.word_topic, k),
                snapshot: &self.snapshot,
                h: self.h,
                seed: self.seed ^ LDA_SWEEP_SALT,
                sweep: sweep_no,
                kernel: self.kernel,
                obs: TaskObs {
                    trace: self.tracer.as_deref(),
                    epoch: l as u32,
                    family: Family::Word as u8,
                },
            };
            let tasks = EpochTasks {
                blocks: &mut diag,
                ids: &ids,
                assign: &ep.assign,
                nanos: &mut self.task_nanos[..n],
                worker_nanos: &mut self.worker_nanos,
                steal,
            };
            let shards = &mut self.shards;
            let mut io_write = 0.0f64;
            // Release before prefetch: freeing the previous diagonal
            // first keeps the budget check seeing at most two diagonals,
            // exactly like the barrier path's residency profile.
            let mut overlap = || {
                if l > 0 {
                    io_write += shards
                        .release(l - 1)
                        .expect("out-of-core: writing a diagonal back to the shard store failed");
                }
                if p > 1 {
                    shards.prefetch((l + 1) % p);
                }
            };
            let topic = &mut self.counts.topic;
            let tr_commit = self.tracer.as_deref();
            let mut runahead = 0.0f64;
            let mut blocking = 0.0f64;
            // The committer runs on the coordinator thread in every
            // executor, so its spans go to the coordinator lane.
            let mut commit = |t: usize, delta: &[i64], in_flight: usize| {
                let fold_started = Instant::now();
                commit_delta(topic, delta);
                let secs = fold_started.elapsed().as_secs_f64();
                if in_flight > 0 {
                    runahead += secs;
                } else {
                    blocking += secs;
                }
                if let Some(tr) = tr_commit {
                    let dur = (secs * 1e9) as u64;
                    tr.emit(Event {
                        lane: tr.coord_lane(),
                        sweep: sweep_no as u32,
                        epoch: l as u32,
                        ticket: t as u32,
                        t0_ns: tr.now().saturating_sub(dur),
                        dur_ns: dur,
                        arg: in_flight as u64,
                        ..Event::of(EventKind::Commit)
                    });
                }
            };
            exec.run_epoch_ticketed(&spec, tasks, &mut self.deltas[..n], &mut overlap, &mut commit);
            let m = &self.metrics;
            m.add_phase(Family::Word, Phase::Sample, epoch_started.elapsed());
            m.add_phase_secs(Family::Word, Phase::SpillWrite, io_write);
            m.add_phase_secs(Family::Word, Phase::Runahead, runahead);
            m.add_phase_secs(Family::Word, Phase::Commit, blocking);
            stats.task_nanos.push(self.task_nanos[..n].to_vec());
            stats.worker_nanos.push(self.worker_nanos.clone());
            self.trace_io(sweep_no, l, EventKind::IoWrite, io_write, spill);

            // The epoch drained: every delta is already folded into the
            // authoritative totals, so the "barrier" is one O(K)
            // snapshot republish for the next epoch's readers.
            let barrier_started = Instant::now();
            self.snapshot.copy_from_slice(&self.counts.topic);
            let barrier_dur = barrier_started.elapsed();
            self.metrics
                .add_phase(Family::Word, Phase::Barrier, barrier_dur);
            stats.epoch_secs.push(epoch_started.elapsed().as_secs_f64());
            if let Some(tr) = self.tracer.as_deref() {
                let bns = barrier_dur.as_nanos() as u64;
                tr.emit(Event {
                    lane: tr.coord_lane(),
                    sweep: sweep_no as u32,
                    epoch: l as u32,
                    t0_ns: tr.now().saturating_sub(bns),
                    dur_ns: bns,
                    ..Event::of(EventKind::Barrier)
                });
                let t0 = epoch_t0.unwrap_or(0);
                tr.emit(Event {
                    lane: tr.coord_lane(),
                    sweep: sweep_no as u32,
                    epoch: l as u32,
                    t0_ns: t0,
                    dur_ns: tr.now().saturating_sub(t0),
                    ..Event::of(EventKind::Epoch)
                });
            }
            self.shards.restore_diagonal(l, diag);
        }
        // The last diagonal has no successor epoch to shadow its
        // write-back; flush it here (no-op in-core).
        let write_secs = self
            .shards
            .release(p - 1)
            .expect("out-of-core: writing a diagonal back to the shard store failed");
        self.metrics
            .add_phase_secs(Family::Word, Phase::SpillWrite, write_secs);
        self.trace_io(sweep_no, p - 1, EventKind::IoWrite, write_secs, spill);
    }

    /// The persistent worker pool, if any `Pooled`-mode sweep has run on
    /// this trainer (created on first use, then reused for every epoch).
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.engines.pool()
    }

    /// Run `iters` sweeps, returning the perplexity curve as
    /// `(iteration, perplexity)` pairs.
    ///
    /// `eval_every` is the evaluation cadence: perplexity is recorded
    /// every `eval_every` sweeps and always after the final sweep.
    /// `eval_every == 0` disables perplexity evaluation entirely (the
    /// returned curve is empty) — useful when only the trained counts
    /// matter, since each evaluation costs a full corpus pass.
    pub fn train(
        &mut self,
        bow: &BagOfWords,
        iters: usize,
        eval_every: usize,
        mode: ExecMode,
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for it in 1..=iters {
            self.sweep(mode);
            if eval_every > 0 && (it % eval_every == 0 || it == iters) {
                curve.push((it, self.perplexity(bow)));
            }
        }
        curve
    }

    pub fn perplexity(&self, bow: &BagOfWords) -> f64 {
        perplexity::perplexity(bow, &self.counts, &self.h)
    }

    /// Borrow all resident token blocks (test/diagnostic use; the whole
    /// corpus in-core, at most ~two diagonals in spill mode).
    pub fn all_blocks(&self) -> Vec<&TokenBlock> {
        self.shards.resident_blocks()
    }

    /// The residency policy this trainer runs under.
    pub fn residency(&self) -> Residency {
        self.shards.residency()
    }

    /// High-water mark of resident token bytes (includes in-flight
    /// prefetches; for in-core trainers this is simply the corpus's
    /// token bytes). The memory-budget acceptance tests assert on it.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.shards.peak_resident_bytes()
    }

    /// The spill directory, if this trainer spills.
    pub fn spill_dir(&self) -> Option<&Path> {
        self.shards.store_path()
    }

    /// Keep the spill directory on drop so a later
    /// [`Self::resume_spilled`] can pick the run back up (retires the
    /// prefetch thread; subsequent sweeps load synchronously).
    pub fn keep_spill_store(&mut self) {
        self.shards.keep_store();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};

    fn setup(p: usize, seed: u64) -> (BagOfWords, ParallelLda) {
        let bow = generate(&Profile::tiny(), seed);
        let plan = partition(&bow, p, Algorithm::A3 { restarts: 3 }, seed);
        let lda = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, seed);
        (bow, lda)
    }

    fn setup_scheduled(
        grid: usize,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
    ) -> (BagOfWords, ParallelLda) {
        let bow = generate(&Profile::tiny(), seed);
        let plan = partition(&bow, grid, Algorithm::A3 { restarts: 3 }, seed);
        let lda = ParallelLda::init_scheduled(&bow, &plan, 8, 0.5, 0.1, seed, kind, workers);
        (bow, lda)
    }

    #[test]
    fn init_absorbs_every_token() {
        let (bow, lda) = setup(4, 31);
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda
            .counts
            .check_consistency(&lda.all_blocks())
            .is_ok());
    }

    #[test]
    fn sweep_preserves_invariants() {
        let (bow, mut lda) = setup(3, 32);
        for _ in 0..5 {
            let stats = lda.sweep(ExecMode::Sequential);
            assert_eq!(stats.total_tokens, bow.num_tokens());
            assert_eq!(stats.epoch_secs.len(), 3);
            assert_eq!(stats.workers, 3);
        }
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
    }

    #[test]
    fn threaded_equals_sequential() {
        let (_bow, mut a) = setup(4, 33);
        let (_bow2, mut b) = setup(4, 33);
        for _ in 0..3 {
            a.sweep(ExecMode::Threaded);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn pooled_equals_sequential() {
        let (_bow, mut a) = setup(4, 37);
        let (_bow2, mut b) = setup(4, 37);
        for _ in 0..3 {
            a.sweep(ExecMode::Pooled);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn packed_pooled_matches_sequential_across_worker_counts() {
        // The cross-schedule determinism guarantee: the same grid-4 plan
        // packed onto W ∈ {1, 2, 4} workers and run Pooled is
        // bit-identical to the diagonal Sequential oracle.
        let (_bow, mut oracle) = setup(4, 51);
        for _ in 0..3 {
            oracle.sweep(ExecMode::Sequential);
        }
        for workers in [1usize, 2, 4] {
            let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
            let (_b, mut lda) = setup_scheduled(4, 51, kind, workers);
            assert_eq!(lda.workers(), workers);
            for _ in 0..3 {
                lda.sweep(ExecMode::Pooled);
            }
            assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "W={workers}");
            assert_eq!(lda.counts.word_topic, oracle.counts.word_topic, "W={workers}");
            assert_eq!(lda.counts.topic, oracle.counts.topic, "W={workers}");
            if workers > 1 {
                let pool = lda.pool().expect("pooled sweeps materialize the pool");
                assert_eq!(pool.workers(), workers);
            }
        }
    }

    #[test]
    fn schedules_and_modes_can_be_switched_between_sweeps() {
        // RNG streams are keyed by (sweep, partition), so a trainer may
        // re-schedule AND switch executors mid-training without changing
        // results.
        let (_bow, mut a) = setup_scheduled(4, 52, ScheduleKind::Packed { grid_factor: 2 }, 2);
        let (_bow2, mut b) = setup(4, 52);
        a.sweep(ExecMode::Pooled);
        a.set_schedule(ScheduleKind::Diagonal, 4);
        a.sweep(ExecMode::Threaded);
        a.set_schedule(ScheduleKind::Packed { grid_factor: 4 }, 1);
        a.sweep(ExecMode::Pooled);
        a.set_schedule(ScheduleKind::Packed { grid_factor: 2 }, 2);
        a.sweep(ExecMode::Sequential);
        for _ in 0..4 {
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn packed_epoch_cost_is_assigned_load_not_block_max() {
        // Under packing, epoch_max_tokens reports per-worker assigned
        // sums; their total (measured_cost) can only be <= the diagonal
        // cost of the same plan run unpacked, and with W < P it must be
        // >= total/W per epoch.
        let (_bow, mut packed) = setup_scheduled(4, 53, ScheduleKind::Packed { grid_factor: 2 }, 2);
        let (_bow2, mut diag) = setup(4, 53);
        let sp = packed.sweep(ExecMode::Sequential);
        let sd = diag.sweep(ExecMode::Sequential);
        assert_eq!(sp.total_tokens, sd.total_tokens);
        assert_eq!(sp.workers, 2);
        assert!(
            sp.measured_cost() <= sd.measured_cost() * 2,
            "2-worker packed cost can at most double the 4-worker diagonal cost"
        );
        for (l, &c) in sp.epoch_max_tokens.iter().enumerate() {
            let epoch_total: u64 = packed.schedule().epoch_loads(&packed.costs, l).iter().sum();
            assert!(c >= epoch_total.div_ceil(2), "critical path >= mean load");
        }
    }

    #[test]
    fn pool_is_reused_across_sweeps() {
        let (_bow, mut lda) = setup(4, 38);
        assert!(lda.pool().is_none(), "no pool before the first pooled sweep");
        lda.sweep(ExecMode::Pooled);
        let (workers, epochs) = {
            let pool = lda.pool().expect("pool created on first pooled sweep");
            (pool.workers(), pool.epochs_run())
        };
        assert_eq!(workers, 4);
        assert_eq!(epochs, 4, "P epochs per sweep");
        for _ in 0..3 {
            lda.sweep(ExecMode::Pooled);
        }
        let pool = lda.pool().unwrap();
        // Same pool object served every sweep: worker count stable, epoch
        // counter monotone — no teardown/respawn between sweeps.
        assert_eq!(pool.workers(), 4);
        assert_eq!(pool.epochs_run(), 16);
    }

    #[test]
    fn modes_can_be_mixed_across_sweeps() {
        // RNG streams are keyed by schedule position, so a trainer may
        // switch executors between sweeps without changing results.
        let (_bow, mut a) = setup(3, 39);
        let (_bow2, mut b) = setup(3, 39);
        a.sweep(ExecMode::Pooled);
        a.sweep(ExecMode::Sequential);
        a.sweep(ExecMode::Threaded);
        for _ in 0..3 {
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn pooled_sweep_preserves_invariants() {
        let (bow, mut lda) = setup(3, 40);
        for _ in 0..4 {
            let stats = lda.sweep(ExecMode::Pooled);
            assert_eq!(stats.total_tokens, bow.num_tokens());
        }
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
    }

    #[test]
    fn packed_sweep_preserves_invariants_all_modes() {
        for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
            let (bow, mut lda) =
                setup_scheduled(6, 41, ScheduleKind::Packed { grid_factor: 3 }, 2);
            for _ in 0..3 {
                let stats = lda.sweep(mode);
                assert_eq!(stats.total_tokens, bow.num_tokens());
            }
            assert_eq!(lda.counts.total(), bow.num_tokens());
            assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
        }
    }

    #[test]
    fn every_kernel_is_bit_identical_across_modes_and_workers() {
        // The kernel determinism contract at trainer level: for each
        // kernel, Sequential diagonal is the oracle; Threaded and Pooled
        // under packed schedules at W ∈ {1, 2, 4} must match bit for
        // bit.
        for kernel in KernelKind::all() {
            let (_bow, mut oracle) = setup(4, 71);
            oracle.set_kernel(kernel);
            for _ in 0..3 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                    let (_b, mut lda) = setup_scheduled(4, 71, kind, workers);
                    lda.set_kernel(kernel);
                    assert_eq!(lda.kernel(), kernel);
                    for _ in 0..3 {
                        lda.sweep(mode);
                    }
                    assert_eq!(
                        lda.counts.doc_topic,
                        oracle.counts.doc_topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        lda.counts.word_topic,
                        oracle.counts.word_topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        lda.counts.topic,
                        oracle.counts.topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                }
            }
        }
    }

    #[test]
    fn stealing_is_bit_identical_across_kernels_modes_and_workers() {
        // The stealing acceptance at trainer level: for each kernel,
        // Sequential static diagonal is the oracle; stealing under
        // packed schedules at W ∈ {1, 2, 4} in every exec mode matches
        // bit for bit (assignments become dynamic, results must not).
        for kernel in KernelKind::all() {
            let (_bow, mut oracle) = setup(4, 91);
            oracle.set_kernel(kernel);
            for _ in 0..3 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                    let (_b, mut lda) = setup_scheduled(4, 91, kind, workers);
                    lda.set_kernel(kernel);
                    lda.set_balance(BalanceMode::Steal);
                    assert_eq!(lda.balance(), BalanceMode::Steal);
                    for _ in 0..3 {
                        lda.sweep(mode);
                    }
                    assert_eq!(
                        lda.counts.doc_topic,
                        oracle.counts.doc_topic,
                        "{kernel:?} {mode:?} W={workers} steal"
                    );
                    assert_eq!(
                        lda.counts.word_topic,
                        oracle.counts.word_topic,
                        "{kernel:?} {mode:?} W={workers} steal"
                    );
                    assert_eq!(
                        lda.counts.topic,
                        oracle.counts.topic,
                        "{kernel:?} {mode:?} W={workers} steal"
                    );
                }
            }
        }
    }

    #[test]
    fn stealing_matches_sequential_on_random_schedules() {
        // Property form of the stealing guarantee: random corpora,
        // random (g, W), every kernel — stealing Pooled and Threaded
        // equal the static Sequential oracle bit for bit.
        crate::testing::prop::check("steal-bit-identical", 0x57EA1, 6, |rng| {
            let w = [1usize, 2, 4][rng.gen_range(3)];
            let g = 1 + rng.gen_range(3);
            let p = g * w;
            let bow = crate::testing::prop::gen_bow(rng, 30, 30);
            if bow.num_tokens() == 0 {
                return;
            }
            let plan = partition(&bow, p, Algorithm::A3 { restarts: 1 }, rng.next_u64());
            let kernel = KernelKind::all()[rng.gen_range(3)];
            let kind = ScheduleKind::Packed { grid_factor: g };

            let mut oracle = ParallelLda::init_scheduled(&bow, &plan, 4, 0.5, 0.1, 7, kind, w);
            oracle.set_kernel(kernel);
            for _ in 0..2 {
                oracle.sweep(ExecMode::Sequential);
            }
            for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                let mut lda = ParallelLda::init_scheduled(&bow, &plan, 4, 0.5, 0.1, 7, kind, w);
                lda.set_kernel(kernel);
                lda.set_balance(BalanceMode::Steal);
                for _ in 0..2 {
                    lda.sweep(mode);
                }
                assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "{kernel:?} {mode:?}");
                assert_eq!(
                    lda.counts.word_topic,
                    oracle.counts.word_topic,
                    "{kernel:?} {mode:?}"
                );
                assert_eq!(lda.counts.topic, oracle.counts.topic, "{kernel:?} {mode:?}");
            }
        });
    }

    #[test]
    fn adaptive_repacking_is_bit_identical_and_learns() {
        // Adaptive re-packing moves assignments between sweeps; counts
        // must not move, and the estimator must have learned a rate.
        let (_bow, mut oracle) = setup(4, 93);
        for _ in 0..4 {
            oracle.sweep(ExecMode::Sequential);
        }
        for mode in [ExecMode::Sequential, ExecMode::Pooled] {
            let (_b, mut lda) =
                setup_scheduled(4, 93, ScheduleKind::Packed { grid_factor: 2 }, 2);
            lda.set_balance(BalanceMode::Adaptive);
            lda.set_kernel(KernelKind::Dense);
            for _ in 0..4 {
                lda.sweep(mode);
            }
            assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "{mode:?}");
            assert_eq!(lda.counts.word_topic, oracle.counts.word_topic, "{mode:?}");
            assert_eq!(lda.counts.topic, oracle.counts.topic, "{mode:?}");
            assert!(
                lda.estimator().rate() > 0.0,
                "estimator observed at least one measured task"
            );
        }
    }

    #[test]
    fn balance_modes_can_be_switched_between_sweeps() {
        let (_bow, mut a) = setup_scheduled(4, 94, ScheduleKind::Packed { grid_factor: 2 }, 2);
        let (_bow2, mut b) = setup(4, 94);
        a.sweep(ExecMode::Pooled);
        a.set_balance(BalanceMode::Adaptive);
        a.sweep(ExecMode::Pooled);
        a.set_balance(BalanceMode::Steal);
        a.sweep(ExecMode::Pooled);
        a.set_balance(BalanceMode::Static);
        a.sweep(ExecMode::Sequential);
        for _ in 0..4 {
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn sweep_telemetry_is_conserved_and_bounded() {
        let (bow, mut lda) = setup_scheduled(6, 95, ScheduleKind::Packed { grid_factor: 3 }, 2);
        for mode in [ExecMode::Sequential, ExecMode::Pooled] {
            let stats = lda.sweep(mode);
            assert_eq!(stats.task_nanos.len(), 6);
            assert_eq!(stats.worker_nanos.len(), 6);
            for ws in &stats.worker_nanos {
                assert_eq!(ws.len(), 2);
            }
            // Per-worker busy conserves per-task time.
            let task_total: u64 = stats.task_nanos.iter().flatten().sum();
            assert_eq!(task_total, stats.busy_total_nanos(), "{mode:?}");
            assert!(task_total > 0, "a real sweep takes measurable time");
            // Eq. 2 on wallclock: 1/W ≤ η ≤ 1.
            let eta = stats.measured_eta();
            assert!(eta > 0.0 && eta <= 1.0 + 1e-12, "{mode:?}: measured eta {eta}");
            assert!(stats.crit_nanos() >= task_total / 2, "crit >= mean over W=2");
            // Busy + idle per worker is constant (= Σ_l crit_l).
            let busy = stats.worker_busy();
            let idle = stats.worker_idle();
            let crit = stats.crit_nanos();
            for w in 0..2 {
                assert_eq!(busy[w] + idle[w], crit, "{mode:?} worker {w}");
            }
            assert_eq!(stats.total_tokens, bow.num_tokens());
            assert!(stats.sample_secs > 0.0);
        }
    }

    #[test]
    fn sparse_and_alias_training_reduces_perplexity() {
        for kernel in [KernelKind::Sparse, KernelKind::Alias] {
            let (bow, mut lda) = setup(4, 72);
            lda.set_kernel(kernel);
            let p0 = lda.perplexity(&bow);
            let curve = lda.train(&bow, 30, 30, ExecMode::Sequential);
            let p_end = curve.last().unwrap().1;
            assert!(p_end < p0 * 0.9, "{kernel:?}: {p0} → {p_end}");
        }
    }

    #[test]
    fn kernel_switch_mid_training_keeps_invariants() {
        let (bow, mut lda) = setup(3, 73);
        for kernel in [
            KernelKind::Dense,
            KernelKind::Sparse,
            KernelKind::Alias,
            KernelKind::Dense,
        ] {
            lda.set_kernel(kernel);
            lda.sweep(ExecMode::Pooled);
        }
        assert_eq!(lda.counts.total(), bow.num_tokens());
        assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
    }

    #[test]
    fn parallel_training_reduces_perplexity() {
        let (bow, mut lda) = setup(4, 34);
        let p0 = lda.perplexity(&bow);
        let curve = lda.train(&bow, 30, 30, ExecMode::Sequential);
        let p_end = curve.last().unwrap().1;
        assert!(p_end < p0 * 0.9, "{p0} → {p_end}");
    }

    #[test]
    fn parallel_close_to_serial_perplexity() {
        // Table IV's claim in miniature: parallel and serial converge to
        // approximately the same training perplexity.
        let bow = generate(&Profile::tiny(), 35);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 35);
        let mut par = ParallelLda::init(&bow, &plan, 8, 0.5, 0.1, 35);
        let mut ser = crate::gibbs::serial::SerialLda::init(&bow, 8, 0.5, 0.1, 35);
        par.train(&bow, 40, 0, ExecMode::Sequential);
        ser.train(&bow, 40, 0);
        let pp = par.perplexity(&bow);
        let ps = ser.perplexity(&bow);
        let rel = (pp - ps).abs() / ps;
        assert!(rel < 0.05, "parallel {pp} vs serial {ps} (rel {rel})");
    }

    #[test]
    fn exec_mode_parses_cli_spellings() {
        assert_eq!(ExecMode::parse("sequential"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("threads"), Some(ExecMode::Threaded));
        assert_eq!(ExecMode::parse("pooled"), Some(ExecMode::Pooled));
        assert_eq!(ExecMode::parse("pool"), Some(ExecMode::Pooled));
        assert_eq!(ExecMode::parse("gpu"), None);
        assert_eq!(ExecMode::Pooled.name(), "pooled");
    }

    #[test]
    fn measured_cost_matches_plan_cost() {
        let bow = generate(&Profile::tiny(), 36);
        let plan = partition(&bow, 5, Algorithm::A1, 36);
        let mut lda = ParallelLda::init(&bow, &plan, 4, 0.5, 0.1, 36);
        let stats = lda.sweep(ExecMode::Sequential);
        assert_eq!(stats.measured_cost() as f64, plan.cost);
    }

    #[test]
    fn measured_cost_matches_schedule_cost_under_packing() {
        let bow = generate(&Profile::tiny(), 42);
        let plan = partition(&bow, 8, Algorithm::A3 { restarts: 2 }, 42);
        let mut lda = ParallelLda::init_scheduled(
            &bow,
            &plan,
            4,
            0.5,
            0.1,
            42,
            ScheduleKind::Packed { grid_factor: 4 },
            2,
        );
        let stats = lda.sweep(ExecMode::Sequential);
        assert_eq!(stats.measured_cost(), lda.schedule().cost(&plan.costs));
    }

    fn setup_resident(
        grid: usize,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
        residency: Residency,
    ) -> (BagOfWords, ParallelLda) {
        let bow = generate(&Profile::tiny(), seed);
        let plan = partition(&bow, grid, Algorithm::A3 { restarts: 3 }, seed);
        let lda =
            ParallelLda::init_resident(&bow, &plan, 8, 0.5, 0.1, seed, kind, workers, residency)
                .expect("spill init");
        (bow, lda)
    }

    #[test]
    fn spill_matches_in_core_across_kernels_modes_and_workers() {
        // The out-of-core acceptance matrix at trainer level: for every
        // kernel, exec mode, and worker count, a spilled trainer is
        // bit-identical to the in-core Sequential diagonal oracle.
        let spill = Residency::Spill { budget_bytes: 0 };
        for kernel in KernelKind::all() {
            let (_bow, mut oracle) = setup(4, 121);
            oracle.set_kernel(kernel);
            for _ in 0..3 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                    let (_b, mut lda) = setup_resident(4, 121, kind, workers, spill);
                    assert_eq!(lda.residency(), spill);
                    lda.set_kernel(kernel);
                    for _ in 0..3 {
                        lda.sweep(mode);
                    }
                    assert_eq!(
                        lda.counts.doc_topic,
                        oracle.counts.doc_topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        lda.counts.word_topic,
                        oracle.counts.word_topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        lda.counts.topic,
                        oracle.counts.topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                }
            }
        }
    }

    #[test]
    fn spill_respects_memory_budget_and_stays_bit_identical() {
        // Budget the spilled trainer to its two largest adjacent
        // diagonals: the sweep must honor the bound (asserted on the
        // high-water mark, which includes in-flight prefetches) while
        // training bit-identically to in-core.
        let (_bow, mut in_core) = setup(4, 122);
        let corpus_bytes = in_core.peak_resident_bytes();
        for _ in 0..3 {
            in_core.sweep(ExecMode::Sequential);
        }
        // Generous two-diagonal budget: in a 4×4 grid one diagonal holds
        // ~1/4 of the corpus, so half the corpus covers current + next.
        let budget = corpus_bytes / 2;
        let spill = Residency::Spill { budget_bytes: budget };
        let (_b, mut lda) = setup_resident(4, 122, ScheduleKind::Diagonal, 4, spill);
        let mut stats = SweepStats::default();
        for _ in 0..3 {
            stats = lda.sweep(ExecMode::Sequential);
        }
        assert_eq!(lda.counts.doc_topic, in_core.counts.doc_topic);
        assert_eq!(lda.counts.word_topic, in_core.counts.word_topic);
        assert_eq!(lda.counts.topic, in_core.counts.topic);
        let peak = lda.peak_resident_bytes();
        assert!(peak > 0, "something was resident");
        assert!(
            peak <= budget,
            "resident token bytes {peak} exceeded the {budget} budget"
        );
        assert!(
            peak < corpus_bytes,
            "spill mode must hold less than the whole corpus ({peak} vs {corpus_bytes})"
        );
        assert!(
            stats.io_write_secs > 0.0,
            "write-back happened and was measured"
        );
    }

    #[test]
    fn spilled_trainer_resumes_from_kept_store() {
        // Crash-safety: stop a spilled run after 2 sweeps, re-open its
        // store, resume for a 3rd — identical to 3 uninterrupted sweeps.
        let (_bow, mut oracle) = setup(4, 123);
        for _ in 0..3 {
            oracle.sweep(ExecMode::Sequential);
        }
        let spill = Residency::Spill { budget_bytes: 0 };
        let dir = {
            let (_b, mut lda) = setup_resident(4, 123, ScheduleKind::Diagonal, 4, spill);
            for _ in 0..2 {
                lda.sweep(ExecMode::Sequential);
            }
            lda.keep_spill_store();
            lda.sweep(ExecMode::Sequential); // kept stores keep training
            let dir = lda.spill_dir().expect("spilled trainer has a dir").to_path_buf();
            assert_eq!(lda.counts.topic, oracle.counts.topic, "pre-drop sanity");
            drop(lda);
            dir
        };
        assert!(dir.is_dir(), "kept store survives the trainer");

        // Rebuild from the store at sweeps_done = 3... then roll a 4th
        // sweep on both and compare everything.
        let bow = generate(&Profile::tiny(), 123);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 123);
        // A wrong sweep count (== a store a crash left mid-sweep) is
        // refused via the per-block sweep stamps, not trained from.
        let err = match ParallelLda::resume_spilled(
            &bow,
            &plan,
            8,
            0.5,
            0.1,
            123,
            ScheduleKind::Diagonal,
            4,
            &dir,
            2,
            spill,
        ) {
            Ok(_) => panic!("a mismatched-stamp store must be refused"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("sweep stamp 3"), "{err}");
        for residency in [Residency::InCore, spill] {
            let mut resumed = ParallelLda::resume_spilled(
                &bow,
                &plan,
                8,
                0.5,
                0.1,
                123,
                ScheduleKind::Diagonal,
                4,
                &dir,
                3,
                residency,
            )
            .expect("resume");
            assert_eq!(
                resumed.counts.doc_topic, oracle.counts.doc_topic,
                "{residency:?}: counts reconstructed from stored blocks"
            );
            assert_eq!(resumed.counts.word_topic, oracle.counts.word_topic);
            assert_eq!(resumed.counts.topic, oracle.counts.topic);
            let mut fresh = {
                let (_b, lda) = setup(4, 123);
                lda
            };
            for _ in 0..4 {
                fresh.sweep(ExecMode::Sequential);
            }
            resumed.sweep(ExecMode::Sequential);
            assert_eq!(
                resumed.counts.doc_topic, fresh.counts.doc_topic,
                "{residency:?}: sweep 4 continues the chain bit-identically"
            );
            assert_eq!(resumed.counts.word_topic, fresh.counts.word_topic);
            assert_eq!(resumed.counts.topic, fresh.counts.topic);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn export_and_resume_from_store_roundtrip() {
        // The checkpoint primitive: export a trainer's blocks between
        // sweeps, rebuild a fresh trainer from the exported store (under
        // either residency), continue — bit-identical to the
        // uninterrupted run, and the exported store is left untouched
        // (re-resumable).
        let (_bow, mut oracle) = setup(4, 124);
        for _ in 0..4 {
            oracle.sweep(ExecMode::Sequential);
        }
        let (_b, mut lda) = setup(4, 124);
        for _ in 0..2 {
            lda.sweep(ExecMode::Sequential);
        }
        let store = ShardStore::create_temp("export-test").expect("create export store");
        lda.export_blocks(&store).expect("export");
        assert_eq!(lda.sweeps_done(), 2);
        assert_eq!(lda.seed(), 124);
        drop(lda);

        let bow = generate(&Profile::tiny(), 124);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 3 }, 124);
        // A wrong sweep count is refused via the stamps, exactly like
        // resume_spilled.
        assert!(ParallelLda::resume_from_store(
            &bow,
            &plan,
            8,
            0.5,
            0.1,
            124,
            ScheduleKind::Diagonal,
            4,
            &store,
            1,
            Residency::InCore,
        )
        .is_err());
        for residency in [Residency::InCore, Residency::Spill { budget_bytes: 0 }] {
            let mut resumed = ParallelLda::resume_from_store(
                &bow,
                &plan,
                8,
                0.5,
                0.1,
                124,
                ScheduleKind::Diagonal,
                4,
                &store,
                2,
                residency,
            )
            .expect("resume from exported store");
            assert_eq!(resumed.sweeps_done(), 2);
            for _ in 0..2 {
                resumed.sweep(ExecMode::Sequential);
            }
            assert_eq!(
                resumed.counts.doc_topic, oracle.counts.doc_topic,
                "{residency:?}: resumed run continues the chain bit-identically"
            );
            assert_eq!(resumed.counts.word_topic, oracle.counts.word_topic);
            assert_eq!(resumed.counts.topic, oracle.counts.topic);
        }
    }

    #[test]
    fn ticketed_commit_is_bit_identical_across_kernels_modes_and_workers() {
        // The ticketed-protocol acceptance matrix at trainer level: for
        // each kernel, the barrier Sequential diagonal run is the
        // oracle; ticketed commit under packed schedules at W ∈ {1, 2,
        // 4} in every exec mode matches bit for bit (the pipeline
        // changes when deltas fold, never what they fold to).
        for kernel in KernelKind::all() {
            let (_bow, mut oracle) = setup(4, 131);
            oracle.set_kernel(kernel);
            for _ in 0..3 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                    let (_b, mut lda) = setup_scheduled(4, 131, kind, workers);
                    lda.set_kernel(kernel);
                    lda.set_commit(CommitMode::Ticketed);
                    assert_eq!(lda.commit(), CommitMode::Ticketed);
                    for _ in 0..3 {
                        lda.sweep(mode);
                    }
                    let tag = format!("{kernel:?} {mode:?} W={workers} ticketed");
                    assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                    assert_eq!(lda.counts.word_topic, oracle.counts.word_topic, "{tag}");
                    assert_eq!(lda.counts.topic, oracle.counts.topic, "{tag}");
                    assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok(), "{tag}");
                }
            }
        }
    }

    #[test]
    fn ticketed_spill_steal_and_adaptive_match_barrier() {
        // The commit × balance × residency corner of the acceptance
        // matrix: ticketed sweeps under stealing, adaptive re-packing,
        // and spill residency all reproduce the barrier Sequential
        // oracle bit for bit.
        let (_bow, mut oracle) = setup(4, 132);
        for _ in 0..3 {
            oracle.sweep(ExecMode::Sequential);
        }
        let spill = Residency::Spill { budget_bytes: 0 };
        for (balance, residency) in [
            (BalanceMode::Static, spill),
            (BalanceMode::Steal, Residency::InCore),
            (BalanceMode::Steal, spill),
            (BalanceMode::Adaptive, Residency::InCore),
        ] {
            for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                let kind = ScheduleKind::Packed { grid_factor: 2 };
                let (_b, mut lda) = setup_resident(4, 132, kind, 2, residency);
                lda.set_commit(CommitMode::Ticketed);
                lda.set_balance(balance);
                for _ in 0..3 {
                    lda.sweep(mode);
                }
                let tag = format!("{balance:?} {residency:?} {mode:?} ticketed");
                assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                assert_eq!(lda.counts.word_topic, oracle.counts.word_topic, "{tag}");
                assert_eq!(lda.counts.topic, oracle.counts.topic, "{tag}");
            }
        }
    }

    #[test]
    fn commit_modes_can_be_switched_between_sweeps() {
        // The commit protocol is result-invariant, so it may be toggled
        // mid-training (like kernels, schedules, and balance modes).
        let (_bow, mut a) = setup_scheduled(4, 133, ScheduleKind::Packed { grid_factor: 2 }, 2);
        let (_bow2, mut b) = setup(4, 133);
        a.sweep(ExecMode::Pooled);
        a.set_commit(CommitMode::Ticketed);
        a.sweep(ExecMode::Pooled);
        a.sweep(ExecMode::Threaded);
        a.set_commit(CommitMode::Barrier);
        a.sweep(ExecMode::Sequential);
        for _ in 0..4 {
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.topic, b.counts.topic);
    }

    #[test]
    fn ticketed_telemetry_moves_barrier_time_into_commit_buckets() {
        let (bow, mut lda) = setup_scheduled(6, 134, ScheduleKind::Packed { grid_factor: 3 }, 2);
        let barrier_stats = lda.sweep(ExecMode::Pooled);
        assert_eq!(barrier_stats.runahead_secs, 0.0, "barrier mode never runs ahead");
        assert_eq!(barrier_stats.commit_secs, 0.0, "barrier mode has no commit bucket");
        assert!(barrier_stats.barrier_secs > 0.0, "barrier merge is measured");
        lda.set_commit(CommitMode::Ticketed);
        let stats = lda.sweep(ExecMode::Pooled);
        assert_eq!(stats.total_tokens, bow.num_tokens());
        assert_eq!(stats.epoch_secs.len(), 6);
        // Every delta fold lands in exactly one of the two new buckets.
        assert!(stats.runahead_secs + stats.commit_secs > 0.0, "folds were timed");
        // The telemetry contracts (conservation, Eq. 2 bounds) hold
        // under the ticketed protocol too.
        let task_total: u64 = stats.task_nanos.iter().flatten().sum();
        assert_eq!(task_total, stats.busy_total_nanos());
        assert!(task_total > 0);
        let eta = stats.measured_eta();
        assert!(eta > 0.0 && eta <= 1.0 + 1e-12, "measured eta {eta}");
        assert!(stats.sample_secs > 0.0);
    }

    #[test]
    fn ticketed_matches_barrier_on_random_schedules() {
        // Property form of the ticketed guarantee: random corpora,
        // random (g, W), every kernel — ticketed Pooled ≡ barrier
        // Pooled ≡ barrier Sequential, bit for bit.
        crate::testing::prop::check("ticketed-bit-identical", 0x71C4ED, 6, |rng| {
            let w = [1usize, 2, 4][rng.gen_range(3)];
            let g = 1 + rng.gen_range(3);
            let p = g * w;
            let bow = crate::testing::prop::gen_bow(rng, 30, 30);
            if bow.num_tokens() == 0 {
                return;
            }
            let plan = partition(&bow, p, Algorithm::A3 { restarts: 1 }, rng.next_u64());
            let kernel = KernelKind::all()[rng.gen_range(3)];
            let kind = ScheduleKind::Packed { grid_factor: g };

            let mut oracle = ParallelLda::init_scheduled(&bow, &plan, 4, 0.5, 0.1, 7, kind, w);
            oracle.set_kernel(kernel);
            let mut barrier = ParallelLda::init_scheduled(&bow, &plan, 4, 0.5, 0.1, 7, kind, w);
            barrier.set_kernel(kernel);
            let mut ticketed = ParallelLda::init_scheduled(&bow, &plan, 4, 0.5, 0.1, 7, kind, w);
            ticketed.set_kernel(kernel);
            ticketed.set_commit(CommitMode::Ticketed);
            for _ in 0..2 {
                oracle.sweep(ExecMode::Sequential);
                barrier.sweep(ExecMode::Pooled);
                ticketed.sweep(ExecMode::Pooled);
            }
            assert_eq!(barrier.counts.topic, oracle.counts.topic, "{kernel:?} barrier");
            assert_eq!(ticketed.counts.doc_topic, oracle.counts.doc_topic, "{kernel:?}");
            assert_eq!(ticketed.counts.word_topic, oracle.counts.word_topic, "{kernel:?}");
            assert_eq!(ticketed.counts.topic, oracle.counts.topic, "{kernel:?}");
        });
    }

    #[test]
    fn commit_mode_parses_cli_spellings() {
        assert_eq!(CommitMode::parse("barrier"), Some(CommitMode::Barrier));
        assert_eq!(CommitMode::parse("ticketed"), Some(CommitMode::Ticketed));
        assert_eq!(CommitMode::parse("ticket"), Some(CommitMode::Ticketed));
        assert_eq!(CommitMode::parse("async"), None);
        assert_eq!(CommitMode::Ticketed.name(), "ticketed");
        assert_eq!(CommitMode::default(), CommitMode::Barrier);
    }

    /// The LDA fault-tolerance acceptance matrix: one injected worker
    /// panic (and, when spilling, one transient IO error plus one torn
    /// spill write) per training run, across kernels × exec modes ×
    /// residency — every run must complete and match the undisturbed
    /// Sequential oracle bit for bit, with the retries surfaced in the
    /// sweep telemetry.
    #[cfg(feature = "failpoints")]
    mod fault_injection {
        use super::*;
        use crate::util::fault::{self, install, Fault, FaultKind, ANY};

        #[test]
        fn faulted_training_matches_oracle_across_kernels_modes_and_residency() {
            const SEED: u64 = 0xFA17_0011;
            let spill = Residency::Spill { budget_bytes: 0 };
            for kernel in KernelKind::all() {
                let (_bow, mut oracle) = setup(4, SEED);
                oracle.set_kernel(kernel);
                for _ in 0..3 {
                    oracle.sweep(ExecMode::Sequential);
                }
                for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                    for residency in [Residency::InCore, spill] {
                        let (_b, mut lda) =
                            setup_resident(4, SEED, ScheduleKind::Diagonal, 4, residency);
                        lda.set_kernel(kernel);
                        let mut faults = vec![Fault {
                            site: "task",
                            key: [SEED ^ LDA_SWEEP_SALT, 0, ANY],
                            kind: FaultKind::Panic,
                        }];
                        if let Some(dir) = lda.spill_dir() {
                            let token = fault::path_token(dir);
                            faults.push(Fault {
                                site: "shard.read",
                                key: [token, ANY, ANY],
                                kind: FaultKind::IoError,
                            });
                            faults.push(Fault {
                                site: "shard.write_z",
                                key: [token, ANY, ANY],
                                kind: FaultKind::TornWrite,
                            });
                        }
                        let guard = install(faults);
                        let mut task_retries = 0u64;
                        let mut io_retries = 0u64;
                        for _ in 0..3 {
                            let stats = lda.sweep(mode);
                            task_retries += stats.task_retries;
                            io_retries += stats.io_retries;
                        }
                        drop(guard);
                        let tag = format!("{kernel:?} {mode:?} {residency:?}");
                        assert_eq!(task_retries, 1, "{tag}: one contained panic, one retry");
                        if residency == spill {
                            assert_eq!(io_retries, 2, "{tag}: torn write + IO error retried");
                        } else {
                            assert_eq!(io_retries, 0, "{tag}: in-core performs no IO");
                        }
                        assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                        assert_eq!(lda.counts.word_topic, oracle.counts.word_topic, "{tag}");
                        assert_eq!(lda.counts.topic, oracle.counts.topic, "{tag}");
                        if residency == Residency::InCore {
                            assert!(
                                lda.counts.check_consistency(&lda.all_blocks()).is_ok(),
                                "{tag}"
                            );
                        }
                    }
                }
            }
        }

        #[test]
        fn ticketed_commit_faults_roll_back_tickets_and_match_oracle() {
            // The run-ahead rollback acceptance: a worker that crashes
            // *after* sampling but before its result reaches the
            // committer (the `commit` failpoint) revokes its ticket —
            // the committer's watermark stalls, nothing after it
            // commits, and the retry re-executes the identical
            // `(seed, sweep, partition)` RNG stream after the exact
            // count rollback. Matrix over exec modes × residency, with
            // a mid-sampling crash on the next sweep covering the other
            // revocation path; the undisturbed barrier Sequential run
            // is the oracle.
            const SEED: u64 = 0xFA17_0041;
            let spill = Residency::Spill { budget_bytes: 0 };
            let (_bow, mut oracle) = setup(4, SEED);
            for _ in 0..3 {
                oracle.sweep(ExecMode::Sequential);
            }
            for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                for residency in [Residency::InCore, spill] {
                    let (_b, mut lda) =
                        setup_resident(4, SEED, ScheduleKind::Diagonal, 4, residency);
                    lda.set_commit(CommitMode::Ticketed);
                    let guard = install(vec![
                        Fault {
                            site: fault::sites::COMMIT,
                            key: [SEED ^ LDA_SWEEP_SALT, 0, ANY],
                            kind: FaultKind::Panic,
                        },
                        Fault {
                            site: fault::sites::TASK,
                            key: [SEED ^ LDA_SWEEP_SALT, 1, ANY],
                            kind: FaultKind::Panic,
                        },
                    ]);
                    let mut task_retries = 0u64;
                    for _ in 0..3 {
                        task_retries += lda.sweep(mode).task_retries;
                    }
                    drop(guard);
                    let tag = format!("{mode:?} {residency:?} ticketed");
                    assert_eq!(task_retries, 2, "{tag}: two contained panics, two retries");
                    assert_eq!(lda.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                    assert_eq!(lda.counts.word_topic, oracle.counts.word_topic, "{tag}");
                    assert_eq!(lda.counts.topic, oracle.counts.topic, "{tag}");
                }
            }
        }
    }

    mod tracing {
        use super::*;
        use crate::obs::analyze::analyze;
        use crate::obs::{Family, TraceMeta, Tracer};
        use std::sync::Arc;

        fn traced(
            mut lda: ParallelLda,
            mode: ExecMode,
            sweeps: usize,
        ) -> (ParallelLda, Arc<Tracer>) {
            let tr = Arc::new(Tracer::new(lda.workers()));
            lda.set_tracer(Some(Arc::clone(&tr)));
            for _ in 0..sweeps {
                lda.sweep(mode);
            }
            (lda, tr)
        }

        #[test]
        fn tracing_on_equals_off_across_kernels_modes_and_commits() {
            // The observational contract: attaching a tracer changes no
            // sampled bit, for every kernel x exec mode x commit mode.
            for kernel in KernelKind::all() {
                for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                    for commit in [CommitMode::Barrier, CommitMode::Ticketed] {
                        let (_b, mut plain) = setup(3, 0xB17);
                        plain.set_kernel(kernel);
                        plain.set_commit(commit);
                        for _ in 0..2 {
                            plain.sweep(mode);
                        }
                        let (_b2, mut lda) = setup(3, 0xB17);
                        lda.set_kernel(kernel);
                        lda.set_commit(commit);
                        let (lda, tr) = traced(lda, mode, 2);
                        let tag = format!("{kernel:?} {mode:?} {commit:?}");
                        assert_eq!(tr.dropped(), 0, "{tag}");
                        assert!(!tr.take().is_empty(), "{tag}: trace recorded");
                        assert_eq!(lda.counts.doc_topic, plain.counts.doc_topic, "{tag}");
                        assert_eq!(lda.counts.word_topic, plain.counts.word_topic, "{tag}");
                        assert_eq!(lda.counts.topic, plain.counts.topic, "{tag}");
                    }
                }
            }
        }

        #[test]
        fn trace_covers_every_task_exactly_once_under_pooled_steal() {
            // Ring-buffer drain acceptance: with the persistent pool
            // and work stealing racing the coordinator, the drained
            // stream still holds exactly one Task span per scheduled
            // task per sweep -- no losses, no duplicates. The analyzer
            // enforces this (per-epoch ticket sets must be exactly
            // {0..n-1} with distinct partitions).
            let sweeps = 3usize;
            let grid = 4usize;
            let (_b, mut lda) =
                setup_scheduled(grid, 0x5EA1, ScheduleKind::Packed { grid_factor: 2 }, 2);
            lda.set_balance(BalanceMode::Steal);
            let (lda, tr) = traced(lda, ExecMode::Pooled, sweeps);
            assert_eq!(tr.dropped(), 0);
            let events = tr.take();
            let meta = TraceMeta {
                workers: lda.workers(),
                dropped: 0,
                label: String::new(),
            };
            let an = analyze(&events, &meta).expect("trace passes span-schema validation");
            let tasks: u64 = an.sweeps.iter().map(|s| s.tasks).sum();
            assert_eq!(tasks as usize, sweeps * grid * grid);
            assert_eq!(an.sweeps.len(), sweeps, "one row per (family, sweep)");
            assert_eq!(an.task_ns.count(), tasks);
        }

        #[test]
        fn analyzer_eta_matches_trainer_registry() {
            // The analyzer recomputes measured-eta from raw Task spans
            // with the trainer's own accounting (busy / (W * sum of
            // per-epoch max-lane busy)); both views must agree to
            // within 1%.
            let (_b, mut lda) =
                setup_scheduled(4, 0xE7A, ScheduleKind::Packed { grid_factor: 2 }, 2);
            lda.set_commit(CommitMode::Ticketed);
            let (lda, tr) = traced(lda, ExecMode::Pooled, 3);
            assert_eq!(tr.dropped(), 0);
            let events = tr.take();
            let meta = TraceMeta {
                workers: lda.workers(),
                dropped: 0,
                label: String::new(),
            };
            let an = analyze(&events, &meta).expect("valid trace");
            let trainer = lda.metrics().measured_eta(Family::Word, lda.workers());
            let traced_eta = an.measured_eta();
            assert!(
                (traced_eta - trainer).abs() <= 0.01 * trainer,
                "trace eta {traced_eta} vs trainer eta {trainer}"
            );
            // Commit spans cover every ticket under the ticketed mode.
            assert_eq!(an.commit_blocking + an.commit_runahead, 3 * 4 * 4);
        }

        #[test]
        fn sweep_stats_secs_are_registry_views() {
            // Satellite of the registry refactor: the SweepStats
            // second-buckets are per-sweep deltas of the registry phase
            // accounts, so their totals reconcile exactly.
            let (_b, mut lda) = setup(3, 0x51A7);
            let mut sample = 0.0;
            let mut barrier = 0.0;
            let mut update = 0.0;
            for _ in 0..3 {
                let s = lda.sweep(ExecMode::Sequential);
                sample += s.sample_secs;
                barrier += s.barrier_secs;
                update += s.update_secs;
            }
            let m = lda.metrics();
            assert_eq!(m.sweeps.get(), 3);
            assert_eq!(m.tasks.get(), 3 * 9);
            assert_eq!(m.task_ns.count(), 3 * 9);
            let close = |a: f64, b: u64| (a - b as f64 / 1e9).abs() < 1e-6;
            assert!(close(sample, m.phase_nanos(Family::Word, Phase::Sample)));
            assert!(close(barrier, m.phase_nanos(Family::Word, Phase::Barrier)));
            assert!(close(update, m.phase_nanos(Family::Word, Phase::Update)));
            // The report phase breakdown is a view over the same
            // accounts, with the always-present buckets first.
            let phases = m.phases_secs();
            assert_eq!(phases[0].0, "sample");
            assert_eq!(phases[1].0, "barrier");
            assert_eq!(phases[2].0, "update");
        }
    }
}
