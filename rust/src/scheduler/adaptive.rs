//! Cost-aware adaptive scheduling: estimators that learn what a
//! partition *actually costs* and the balance-mode knob that decides how
//! the executor reacts.
//!
//! The paper scores plans by token counts (Eq. 1–2), which assumes every
//! token costs the same to sample. The dense kernel honours that
//! assumption; the sparse and alias kernels do not — their per-token cost
//! depends on the partition's doc/word topic-sparsity (`k_doc + k_word`)
//! and on alias-table amortization, so two partitions with equal token
//! counts can differ several-fold in wallclock. Token-count LPT packing
//! ([`crate::scheduler::schedule`]) then systematically mis-balances real
//! sweep time — the exact failure mode the paper attacks, resurfacing one
//! layer down. Two runtime fixes close the gap, both enabled by the
//! determinism contract (task RNG keyed by `(sweep, partition)`, so *any*
//! task-to-worker assignment is bit-identical):
//!
//! * **Adaptive re-packing** ([`BalanceMode::Adaptive`]) — workers stamp
//!   each task's measured sweep nanos into its telemetry slot; a
//!   [`Measured`] estimator folds them into per-partition EWMAs; between
//!   sweeps the trainer calls [`crate::scheduler::schedule::Schedule::repack_with`]
//!   so each diagonal's LPT packing chases measured cost instead of token
//!   counts. The grid never changes — only who runs what.
//! * **Work stealing** ([`BalanceMode::Steal`]) — within a diagonal, idle
//!   workers pull the next unclaimed task from a shared per-diagonal
//!   queue (an atomic cursor over the diagonal's task array), absorbing
//!   both estimator error and machine noise at the cost of one atomic op
//!   per task. See [`crate::scheduler::pool`].
//!
//! Both modes are bit-identical to [`BalanceMode::Static`] (and to the
//! `Sequential` oracle) in trained counts; they differ only in which
//! worker samples which partition, i.e. in wallclock.

use crate::partition::eta::CostMatrix;
use crate::scheduler::schedule::{partition_id, Schedule};

/// How the executor balances per-epoch load across workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BalanceMode {
    /// Token-count LPT packing, fixed at schedule build time (the PR-2
    /// behaviour; exact when per-token cost is uniform).
    Static,
    /// Re-run LPT per diagonal between sweeps against a [`Measured`]
    /// estimator, so assignments chase observed per-partition wallclock.
    Adaptive,
    /// Within-diagonal work stealing: assignments become hints and idle
    /// workers pull from a shared per-diagonal queue at runtime.
    Steal,
}

impl BalanceMode {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "static" => Some(Self::Static),
            "adaptive" | "adapt" => Some(Self::Adaptive),
            "steal" | "stealing" => Some(Self::Steal),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Static => "static",
            Self::Adaptive => "adaptive",
            Self::Steal => "steal",
        }
    }

    /// Whether per-epoch task assignments are hints rather than binding
    /// (idle workers pull from the shared queue at runtime). Trainers
    /// branch on this to skip per-worker speed telemetry, and the
    /// ticketed committer uses the same eligibility rule either way —
    /// ticket order is independent of who sampled what.
    pub fn is_steal(self) -> bool {
        matches!(self, Self::Steal)
    }
}

/// Predicts what one partition's sweep will cost, in abstract cost units
/// (comparable *within* one estimator; LPT only needs relative order and
/// additivity). Implementations observe measured wallclock after every
/// sweep and refine.
pub trait CostEstimator {
    /// Estimated cost of sweeping partition `id` given its `tokens`.
    fn estimate(&self, id: u64, tokens: u64) -> u64;

    /// Record one measured sweep of partition `id`: `tokens` sampled in
    /// `nanos` wallclock.
    fn observe(&mut self, id: u64, tokens: u64, nanos: u64);

    fn name(&self) -> &'static str;
}

/// The paper's proxy: cost = token count. Never learns; packing against
/// it reproduces the static schedule exactly.
#[derive(Clone, Copy, Debug, Default)]
pub struct TokenCount;

impl CostEstimator for TokenCount {
    fn estimate(&self, _id: u64, tokens: u64) -> u64 {
        tokens
    }

    fn observe(&mut self, _id: u64, _tokens: u64, _nanos: u64) {}

    fn name(&self) -> &'static str {
        "tokens"
    }
}

/// EWMA smoothing factor: weight of the newest observation. High enough
/// to track alias-table amortization kicking in after the first sweeps,
/// low enough to ride out scheduler noise on a loaded box.
const EWMA_ALPHA: f64 = 0.4;

/// Per-partition EWMA of observed sweep nanos, seeded from token counts.
///
/// Partitions that have never been measured are estimated as
/// `tokens × rate`, where `rate` is a global EWMA of nanos-per-token over
/// all observations — so before the first sweep the estimator orders
/// partitions exactly like [`TokenCount`] (a constant rate rescales every
/// cost equally, which LPT is invariant to), and each observation then
/// sharpens exactly the partitions it measured.
#[derive(Clone, Debug)]
pub struct Measured {
    /// EWMA nanos per partition id; `NAN` = never observed.
    ewma: Vec<f64>,
    /// Global EWMA of nanos per token (the seed rate for unobserved
    /// partitions); 0 until the first observation.
    rate: f64,
    /// Per-worker-slot EWMA of measured busy nanos over *predicted*
    /// nanos for the same assignment; `NAN` = that slot has never been
    /// measured. Normalizing by the estimator's own per-partition
    /// predictions (not token counts) separates worker speed from
    /// partition difficulty — a slot that keeps drawing expensive
    /// partitions is not a slow core. Heterogeneous boxes (mixed cores,
    /// a worker sharing its core with another process) show up here and
    /// feed [`Self::worker_factors`], so LPT packs against worker speed
    /// as well as partition cost.
    worker_rate: Vec<f64>,
}

impl Measured {
    /// Estimator for a `grid × grid` plan.
    pub fn new(grid: usize) -> Self {
        Self {
            ewma: vec![f64::NAN; grid * grid],
            rate: 0.0,
            worker_rate: Vec::new(),
        }
    }

    /// Observed nanos-per-token rate (0 until the first observation).
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Fold one sweep's per-worker telemetry: `predicted[l][w]` is this
    /// estimator's predicted cost (nanos, or tokens before the first
    /// observations land) of the work the schedule assigned worker `w`
    /// in epoch `l` ([`Self::predicted_worker_loads`]) and `nanos[l][w]`
    /// the busy wallclock it measured. The ratio is a pure speed signal:
    /// partition difficulty is already in the prediction. Meaningless
    /// under work stealing (the assignment is only a hint there), so
    /// trainers skip it in that mode; zero-prediction or zero-nanos
    /// slots teach nothing.
    pub fn observe_workers(&mut self, predicted: &[Vec<u64>], nanos: &[Vec<u64>]) {
        for (lw, nw) in predicted.iter().zip(nanos) {
            for (w, (&pred, &ns)) in lw.iter().zip(nw.iter()).enumerate() {
                if pred == 0 || ns == 0 {
                    continue;
                }
                if self.worker_rate.len() <= w {
                    self.worker_rate.resize(w + 1, f64::NAN);
                }
                let r = ns as f64 / pred as f64;
                let slot = &mut self.worker_rate[w];
                *slot = if slot.is_finite() {
                    (1.0 - EWMA_ALPHA) * *slot + EWMA_ALPHA * r
                } else {
                    r
                };
            }
        }
    }

    /// Predicted per-worker cost of every epoch of `schedule` under this
    /// estimator's current per-partition estimates — the baseline
    /// [`Self::observe_workers`] compares measured busy time against.
    pub fn predicted_worker_loads(&self, schedule: &Schedule, costs: &CostMatrix) -> Vec<Vec<u64>> {
        let p = costs.p();
        schedule
            .epochs
            .iter()
            .enumerate()
            .map(|(l, ep)| {
                ep.assign
                    .iter()
                    .map(|list| {
                        list.iter()
                            .map(|&m| {
                                let m = m as usize;
                                let n = (m + l) % p;
                                self.estimate(partition_id(m, n, p), costs.get(m, n))
                            })
                            .sum()
                    })
                    .collect()
            })
            .collect()
    }

    /// Per-worker relative slowdown factors for `workers` slots,
    /// normalized so the measured slots average 1.0 (unmeasured slots
    /// report 1.0). Uniform until [`Self::observe_workers`] has seen
    /// telemetry, so homogeneous boxes repack exactly as before.
    pub fn worker_factors(&self, workers: usize) -> Vec<f64> {
        let rates: Vec<f64> = (0..workers)
            .map(|w| self.worker_rate.get(w).copied().unwrap_or(f64::NAN))
            .collect();
        let known: Vec<f64> = rates
            .iter()
            .copied()
            .filter(|r| r.is_finite() && *r > 0.0)
            .collect();
        if known.is_empty() {
            return vec![1.0; workers];
        }
        let mean = known.iter().sum::<f64>() / known.len() as f64;
        rates
            .iter()
            .map(|&r| if r.is_finite() && r > 0.0 { r / mean } else { 1.0 })
            .collect()
    }

    /// Fold a whole sweep's telemetry into the estimator: `nanos[l][m]`
    /// is the measured cost of diagonal `l`'s position-`m` partition
    /// under `costs` (zeros are skipped — an unmeasured or empty task
    /// teaches nothing).
    pub fn observe_sweep(&mut self, costs: &CostMatrix, nanos: &[Vec<u64>]) {
        let p = costs.p();
        for (l, diag) in nanos.iter().enumerate() {
            for (m, &ns) in diag.iter().enumerate() {
                if ns == 0 {
                    continue;
                }
                let n = (m + l) % p;
                self.observe(partition_id(m, n, p), costs.get(m, n), ns);
            }
        }
    }

    /// Rebuild `schedule`'s per-diagonal packings against this
    /// estimator's current cost field *and* its per-worker speed factors
    /// (no-op for diagonal schedules; see [`Schedule::repack_hetero`]).
    pub fn repack(&self, schedule: &mut Schedule, costs: &CostMatrix) {
        let p = costs.p();
        let factors = self.worker_factors(schedule.workers);
        schedule.repack_hetero(
            |m, n| self.estimate(partition_id(m, n, p), costs.get(m, n)),
            &factors,
        );
    }
}

impl CostEstimator for Measured {
    fn estimate(&self, id: u64, tokens: u64) -> u64 {
        let e = self.ewma[id as usize];
        if e.is_finite() {
            return e as u64;
        }
        if self.rate > 0.0 {
            return (tokens as f64 * self.rate) as u64;
        }
        tokens
    }

    fn observe(&mut self, id: u64, tokens: u64, nanos: u64) {
        let slot = &mut self.ewma[id as usize];
        *slot = if slot.is_finite() {
            (1.0 - EWMA_ALPHA) * *slot + EWMA_ALPHA * nanos as f64
        } else {
            nanos as f64
        };
        if tokens > 0 {
            let r = nanos as f64 / tokens as f64;
            self.rate = if self.rate > 0.0 {
                (1.0 - EWMA_ALPHA) * self.rate + EWMA_ALPHA * r
            } else {
                r
            };
        }
    }

    fn name(&self) -> &'static str {
        "measured"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::bow::BagOfWords;
    use crate::scheduler::schedule::ScheduleKind;

    #[test]
    fn balance_mode_parses_cli_spellings() {
        assert_eq!(BalanceMode::parse("static"), Some(BalanceMode::Static));
        assert_eq!(BalanceMode::parse("adaptive"), Some(BalanceMode::Adaptive));
        assert_eq!(BalanceMode::parse("adapt"), Some(BalanceMode::Adaptive));
        assert_eq!(BalanceMode::parse("steal"), Some(BalanceMode::Steal));
        assert_eq!(BalanceMode::parse("stealing"), Some(BalanceMode::Steal));
        assert_eq!(BalanceMode::parse("dynamic"), None);
        assert_eq!(BalanceMode::Adaptive.name(), "adaptive");
        assert_eq!(BalanceMode::Steal.name(), "steal");
        assert_eq!(BalanceMode::Static.name(), "static");
    }

    #[test]
    fn token_count_is_identity_and_inert() {
        let mut t = TokenCount;
        assert_eq!(t.estimate(0, 17), 17);
        t.observe(0, 17, 99_999);
        assert_eq!(t.estimate(0, 17), 17, "TokenCount never learns");
        assert_eq!(t.name(), "tokens");
    }

    #[test]
    fn unseeded_measured_orders_like_token_count() {
        let m = Measured::new(4);
        assert_eq!(m.estimate(0, 10), 10);
        assert_eq!(m.estimate(7, 500), 500);
        assert_eq!(m.rate(), 0.0);
    }

    #[test]
    fn observation_overrides_token_seed() {
        let mut m = Measured::new(2);
        // Partition 0: 100 tokens but measured *slow* (10µs); partition
        // 1: 100 tokens, never measured, seeded from the global rate.
        m.observe(0, 100, 10_000);
        assert_eq!(m.estimate(0, 100), 10_000);
        // Seed rate is 100 ns/token, so the unmeasured twin estimates
        // 100 × 100 = 10_000 too — equal until evidence says otherwise.
        assert_eq!(m.estimate(1, 100), 10_000);
        // New evidence: partition 1 is 5× faster per token.
        m.observe(1, 100, 2_000);
        assert_eq!(m.estimate(1, 100), 2_000);
        assert!(m.estimate(0, 100) > m.estimate(1, 100));
    }

    #[test]
    fn ewma_converges_toward_repeated_observations() {
        let mut m = Measured::new(1);
        m.observe(0, 10, 1_000);
        for _ in 0..40 {
            m.observe(0, 10, 5_000);
        }
        let e = m.estimate(0, 10);
        assert!((4_500..=5_000).contains(&e), "EWMA {e} should approach 5000");
    }

    #[test]
    fn repack_chases_measured_cost_not_tokens() {
        // 4×4 grid on 2 workers. Diagonal 0 has partitions with token
        // counts {40, 40, 10, 10}: token-LPT pairs {40,10} {40,10}.
        // But measurement says one of the 10-token partitions is
        // actually the most expensive (alias-rebuild-heavy): the repack
        // must isolate it.
        let mut cells = Vec::new();
        for m in 0..4u32 {
            for n in 0..4u32 {
                let tokens = if m == n { [40u32, 40, 10, 10][m as usize] } else { 1 };
                cells.push((m, n, tokens));
            }
        }
        let bow = BagOfWords::from_triplets(4, 4, cells);
        let costs = CostMatrix::compute_p(&bow, &[0, 1, 2, 3], &[0, 1, 2, 3], 4);
        let mut schedule = Schedule::build(ScheduleKind::Packed { grid_factor: 2 }, &costs, 2);

        let mut est = Measured::new(4);
        // Uniform 100 ns/token everywhere except partition (2,2): its 10
        // tokens take 9000 ns (900 ns/token).
        for m in 0..4usize {
            let id = partition_id(m, m, 4);
            let tokens = costs.get(m, m);
            let nanos = if m == 2 { 9_000 } else { tokens * 100 };
            est.observe(id, tokens, nanos);
        }
        est.repack(&mut schedule, &costs);

        // Under the true (measured) cost field the repacked diagonal-0
        // critical path must isolate the 9µs partition: {9000} vs
        // {4000, 4000, 1000} → crit 9000, not 9000+1000.
        let crit: u64 = schedule.epochs[0]
            .assign
            .iter()
            .map(|list| {
                list.iter()
                    .map(|&m| {
                        let m = m as usize;
                        est.estimate(partition_id(m, m, 4), costs.get(m, m))
                    })
                    .sum::<u64>()
            })
            .max()
            .unwrap();
        assert_eq!(crit, 9_000, "repack must isolate the measured-slow partition");
    }

    #[test]
    fn worker_factors_default_to_uniform_and_learn_from_telemetry() {
        let mut m = Measured::new(4);
        assert_eq!(m.worker_factors(3), vec![1.0; 3], "unmeasured = uniform");
        // Workers 0 and 1 were both predicted 1000 units of work; worker
        // 1 took 3× as long as worker 0; worker 2's prediction is zero
        // (skipped).
        m.observe_workers(
            &[vec![1000, 1000, 0]],
            &[vec![100_000, 300_000, 50_000]],
        );
        let f = m.worker_factors(3);
        assert!((f[0] - 0.5).abs() < 1e-9, "{f:?}");
        assert!((f[1] - 1.5).abs() < 1e-9, "{f:?}");
        assert_eq!(f[2], 1.0, "unmeasured slot stays neutral: {f:?}");
        // Factors normalize over however many slots the caller asks for.
        assert_eq!(m.worker_factors(5).len(), 5);
    }

    #[test]
    fn repack_packs_against_worker_speed() {
        // 4×4 grid, every partition 10 tokens (all costs tied), on 2
        // workers whose measured speeds differ 3×: the repack must give
        // the fast worker 3 of each diagonal's 4 partitions.
        let mut cells = Vec::new();
        for m in 0..4u32 {
            for n in 0..4u32 {
                cells.push((m, n, 10u32));
            }
        }
        let bow = BagOfWords::from_triplets(4, 4, cells);
        let costs = CostMatrix::compute_p(&bow, &[0, 1, 2, 3], &[0, 1, 2, 3], 4);
        let mut schedule = Schedule::build(ScheduleKind::Packed { grid_factor: 2 }, &costs, 2);

        let mut est = Measured::new(4);
        // Equal predicted work, 3x measured gap: worker 1 is slow.
        est.observe_workers(&[vec![20, 20]], &[vec![2_000, 6_000]]);
        est.repack(&mut schedule, &costs);
        for (l, ep) in schedule.epochs.iter().enumerate() {
            assert_eq!(ep.assign[0].len(), 3, "epoch {l}: fast worker takes 3");
            assert_eq!(ep.assign[1].len(), 1, "epoch {l}: slow worker takes 1");
        }
    }

    #[test]
    fn observe_sweep_skips_zeros_and_feeds_every_diagonal() {
        let bow = BagOfWords::from_triplets(2, 2, [(0, 0, 4), (1, 1, 6), (0, 1, 2), (1, 0, 8)]);
        let costs = CostMatrix::compute_p(&bow, &[0, 1], &[0, 1], 2);
        let mut est = Measured::new(2);
        // Diagonal 0 = {(0,0), (1,1)}; diagonal 1 = {(0,1), (1,0)}.
        est.observe_sweep(&costs, &[vec![400, 0], vec![200, 800]]);
        assert_eq!(est.estimate(partition_id(0, 0, 2), 4), 400);
        assert_eq!(est.estimate(partition_id(0, 1, 2), 2), 200);
        assert_eq!(est.estimate(partition_id(1, 0, 2), 8), 800);
        // (1,1) was zero → unobserved → seeded from the global rate.
        let rate = est.rate();
        assert!(rate > 0.0);
        assert_eq!(est.estimate(partition_id(1, 1, 2), 6), (6.0 * rate) as u64);
    }
}
