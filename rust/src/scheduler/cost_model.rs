//! Epoch-cost speedup model (paper §VI-C): with per-token sampling cost
//! roughly uniform, the parallel sweep time is the schedule's critical
//! path `Σ_l max_w assigned_tokens(w, l) / rate` while the serial sweep
//! is `N / rate`, so
//!
//! ```text
//! speedup = N / Σ_l max_w assigned_tokens(w, l) = η · W
//! ```
//!
//! where `W` is the *worker* count the schedule executes on — which the
//! legacy diagonal schedule pins to the grid size `P`, but a packed
//! schedule does not (see [`crate::scheduler::schedule`]). The paper
//! reports η rather than wallclock ("we did not record the exact running
//! time"); this module turns a plan, a schedule, or measured sweep stats
//! into the same speedup estimate, and can project wallclock for a
//! measured single-core sampling rate — which is how the speedup bench
//! reports results on a box with fewer physical cores than `P`.

use crate::partition::eta::eta_of_schedule;
use crate::partition::Plan;
use crate::scheduler::exec::SweepStats;
use crate::scheduler::schedule::Schedule;

/// Speedup projection for one plan/schedule.
#[derive(Clone, Copy, Debug)]
pub struct SpeedupReport {
    /// Worker count the speedup is measured against (`== P` for pure
    /// diagonal execution).
    pub workers: usize,
    pub eta: f64,
    /// Predicted speedup `η·W`.
    pub speedup: f64,
    /// Serial sweep cost in tokens (N).
    pub serial_tokens: u64,
    /// Parallel sweep cost in tokens (schedule critical path; Eq. 1 for
    /// the diagonal schedule).
    pub parallel_tokens: u64,
}

impl SpeedupReport {
    /// Plan executed diagonally on `P` workers (the paper's model).
    pub fn of_plan(plan: &Plan) -> Self {
        let n = plan.costs.total();
        let c = plan.costs.sweep_cost();
        Self {
            workers: plan.p,
            eta: plan.eta,
            speedup: plan.eta * plan.p as f64,
            serial_tokens: n,
            parallel_tokens: c,
        }
    }

    /// Plan executed under an explicit schedule: effective speedup
    /// against the schedule's `W` workers, not the grid size.
    pub fn of_schedule(plan: &Plan, schedule: &Schedule) -> Self {
        let n = plan.costs.total();
        let r = eta_of_schedule(&plan.costs, schedule, n);
        Self {
            workers: schedule.workers,
            eta: r.eta,
            speedup: r.eta * schedule.workers as f64,
            serial_tokens: n,
            parallel_tokens: r.cost as u64,
        }
    }

    /// From measured sweep telemetry (validates the model against the
    /// actual per-worker epoch loads the engine executed; the worker
    /// count comes from the stats themselves).
    pub fn of_stats(stats: &SweepStats) -> Self {
        let workers = stats.workers.max(1);
        let n = stats.total_tokens;
        let c = stats.measured_cost().max(1);
        let eta = n as f64 / workers as f64 / c as f64;
        Self {
            workers,
            eta,
            speedup: eta * workers as f64,
            serial_tokens: n,
            parallel_tokens: c,
        }
    }

    /// Projected parallel sweep seconds given a measured serial sampling
    /// rate (tokens/sec on one core).
    pub fn projected_sweep_secs(&self, tokens_per_sec: f64) -> f64 {
        self.parallel_tokens as f64 / tokens_per_sec
    }
}

/// The wallclock analogue of [`SpeedupReport`], built from per-task sweep
/// telemetry instead of token counts: `η_measured = serial_nanos /
/// (W · crit_nanos)` where `crit_nanos = Σ_l max_w busy(l, w)`. When
/// per-token cost is uniform (dense kernel, quiet box) it coincides with
/// token-η; under the sparse/alias kernels the gap between the two is the
/// imbalance that token-count packing cannot see — and that adaptive
/// re-packing / work stealing recover. Both trainers' reports carry it
/// next to token-η so the gap is visible.
#[derive(Clone, Copy, Debug)]
pub struct MeasuredReport {
    /// Worker count the sweeps executed on.
    pub workers: usize,
    /// Measured-η (1.0 when nothing was measured).
    pub eta: f64,
    /// Measured speedup `η·W`.
    pub speedup: f64,
    /// Serial-equivalent sampling nanos (Σ over all tasks).
    pub serial_nanos: u64,
    /// Measured critical-path nanos (Σ_l max_w busy).
    pub parallel_nanos: u64,
}

impl MeasuredReport {
    /// From one sweep's telemetry.
    pub fn of_stats(stats: &SweepStats) -> Self {
        Self::of_parts(stats.workers, stats.busy_total_nanos(), stats.crit_nanos())
    }

    /// Merged over several sweeps (and/or phases): serial and critical
    /// nanos accumulate, η is the ratio of the totals.
    pub fn of_sweeps<'a>(stats: impl IntoIterator<Item = &'a SweepStats>) -> Self {
        let mut workers = 1;
        let mut serial = 0u64;
        let mut crit = 0u64;
        for s in stats {
            workers = workers.max(s.workers);
            serial += s.busy_total_nanos();
            crit += s.crit_nanos();
        }
        Self::of_parts(workers, serial, crit)
    }

    /// From pre-accumulated totals — for drivers that fold sweeps as
    /// they go instead of retaining every `SweepStats`.
    pub fn of_nanos(workers: usize, serial_nanos: u64, parallel_nanos: u64) -> Self {
        Self::of_parts(workers, serial_nanos, parallel_nanos)
    }

    fn of_parts(workers: usize, serial_nanos: u64, parallel_nanos: u64) -> Self {
        let workers = workers.max(1);
        let eta = if parallel_nanos == 0 {
            1.0
        } else {
            serial_nanos as f64 / (workers as f64 * parallel_nanos as f64)
        };
        Self {
            workers,
            eta,
            speedup: eta * workers as f64,
            serial_nanos,
            parallel_nanos,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};
    use crate::scheduler::exec::{ExecMode, ParallelLda};
    use crate::scheduler::schedule::ScheduleKind;

    #[test]
    fn plan_and_stats_agree() {
        let bow = generate(&Profile::tiny(), 41);
        let plan = partition(&bow, 4, Algorithm::A2, 41);
        let from_plan = SpeedupReport::of_plan(&plan);

        let mut lda = ParallelLda::init(&bow, &plan, 4, 0.5, 0.1, 41);
        let stats = lda.sweep(ExecMode::Sequential);
        let from_stats = SpeedupReport::of_stats(&stats);

        assert_eq!(from_plan.workers, from_stats.workers);
        assert_eq!(from_plan.parallel_tokens, from_stats.parallel_tokens);
        assert_eq!(from_plan.serial_tokens, from_stats.serial_tokens);
        assert!((from_plan.eta - from_stats.eta).abs() < 1e-12);
    }

    #[test]
    fn schedule_and_stats_agree_under_packing() {
        let bow = generate(&Profile::tiny(), 44);
        let plan = partition(&bow, 6, Algorithm::A3 { restarts: 2 }, 44);
        let kind = ScheduleKind::Packed { grid_factor: 3 };
        let mut lda = ParallelLda::init_scheduled(&bow, &plan, 4, 0.5, 0.1, 44, kind, 2);
        let from_schedule = SpeedupReport::of_schedule(&plan, lda.schedule());
        let stats = lda.sweep(ExecMode::Sequential);
        let from_stats = SpeedupReport::of_stats(&stats);

        assert_eq!(from_schedule.workers, 2);
        assert_eq!(from_schedule.parallel_tokens, from_stats.parallel_tokens);
        assert_eq!(from_schedule.serial_tokens, from_stats.serial_tokens);
        assert!((from_schedule.eta - from_stats.eta).abs() < 1e-12);
        // Speedup is bounded by the workers actually used, not the grid.
        assert!(from_schedule.speedup <= 2.0 + 1e-9);
    }

    #[test]
    fn speedup_is_eta_w() {
        let bow = generate(&Profile::tiny(), 42);
        let plan = partition(&bow, 5, Algorithm::A3 { restarts: 3 }, 42);
        let r = SpeedupReport::of_plan(&plan);
        assert_eq!(r.workers, 5);
        assert!((r.speedup - r.eta * 5.0).abs() < 1e-12);
        assert!(r.speedup <= 5.0 + 1e-9);
        assert!(r.speedup >= 1.0 - 1e-9); // eta ≥ 1/W always
    }

    #[test]
    fn measured_report_accumulates_sweeps() {
        let mk = |workers, worker_nanos: Vec<Vec<u64>>| SweepStats {
            workers,
            worker_nanos,
            ..SweepStats::default()
        };
        // Sweep 1: epochs {3, 1} and {2, 2} → crit 3 + 2 = 5, serial 8.
        let a = mk(2, vec![vec![3, 1], vec![2, 2]]);
        // Sweep 2: one epoch {4, 0} → crit 4, serial 4.
        let b = mk(2, vec![vec![4, 0]]);
        let ra = MeasuredReport::of_stats(&a);
        assert_eq!(ra.serial_nanos, 8);
        assert_eq!(ra.parallel_nanos, 5);
        assert!((ra.eta - 8.0 / 10.0).abs() < 1e-12);
        assert!((ra.speedup - ra.eta * 2.0).abs() < 1e-12);
        let r = MeasuredReport::of_sweeps([&a, &b]);
        assert_eq!(r.workers, 2);
        assert_eq!(r.serial_nanos, 12);
        assert_eq!(r.parallel_nanos, 9);
        assert!((r.eta - 12.0 / 18.0).abs() < 1e-12);
        // Unmeasured telemetry degrades to the neutral report.
        let empty = MeasuredReport::of_stats(&mk(4, vec![]));
        assert_eq!(empty.eta, 1.0);
        assert_eq!(empty.speedup, 4.0);
    }

    #[test]
    fn measured_report_agrees_with_executed_sweep() {
        let bow = generate(&Profile::tiny(), 45);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 45);
        let mut lda = ParallelLda::init_scheduled(
            &bow,
            &plan,
            4,
            0.5,
            0.1,
            45,
            ScheduleKind::Packed { grid_factor: 2 },
            2,
        );
        let stats = lda.sweep(ExecMode::Sequential);
        let r = MeasuredReport::of_stats(&stats);
        assert_eq!(r.workers, 2);
        assert!(r.serial_nanos > 0, "sweeps take measurable time");
        assert!(r.eta > 0.0 && r.eta <= 1.0 + 1e-12, "eta {}", r.eta);
        assert!((r.eta - stats.measured_eta()).abs() < 1e-12);
    }

    #[test]
    fn projection_scales_with_rate() {
        let bow = generate(&Profile::tiny(), 43);
        let plan = partition(&bow, 2, Algorithm::A1, 43);
        let r = SpeedupReport::of_plan(&plan);
        let slow = r.projected_sweep_secs(1e6);
        let fast = r.projected_sweep_secs(2e6);
        assert!((slow / fast - 2.0).abs() < 1e-9);
    }
}
