//! Epoch-cost speedup model (paper §VI-C): with per-token sampling cost
//! roughly uniform, the parallel sweep time is `Σ_l max_m tokens(m,l) /
//! rate` while the serial sweep is `N / rate`, so
//!
//! ```text
//! speedup = N / Σ_l max_m tokens(m,l) = η · P
//! ```
//!
//! The paper reports η rather than wallclock ("we did not record the
//! exact running time"); this module turns a plan (or measured sweep
//! stats) into the same speedup estimate, and can project wallclock for a
//! measured single-core sampling rate — which is how the speedup bench
//! reports results on a box with fewer physical cores than `P`.

use crate::partition::Plan;
use crate::scheduler::exec::SweepStats;

/// Speedup projection for one plan.
#[derive(Clone, Copy, Debug)]
pub struct SpeedupReport {
    pub p: usize,
    pub eta: f64,
    /// Predicted speedup `η·P`.
    pub speedup: f64,
    /// Serial sweep cost in tokens (N).
    pub serial_tokens: u64,
    /// Parallel sweep cost in tokens (Eq. 1).
    pub parallel_tokens: u64,
}

impl SpeedupReport {
    pub fn of_plan(plan: &Plan) -> Self {
        let n = plan.costs.total();
        let c = plan.costs.sweep_cost();
        Self {
            p: plan.p,
            eta: plan.eta,
            speedup: plan.eta * plan.p as f64,
            serial_tokens: n,
            parallel_tokens: c,
        }
    }

    /// From measured sweep telemetry (validates the model against the
    /// actual max-token epochs the engine executed).
    pub fn of_stats(stats: &SweepStats, p: usize) -> Self {
        let n = stats.total_tokens;
        let c = stats.measured_cost().max(1);
        let eta = n as f64 / p as f64 / c as f64;
        Self {
            p,
            eta,
            speedup: eta * p as f64,
            serial_tokens: n,
            parallel_tokens: c,
        }
    }

    /// Projected parallel sweep seconds given a measured serial sampling
    /// rate (tokens/sec on one core).
    pub fn projected_sweep_secs(&self, tokens_per_sec: f64) -> f64 {
        self.parallel_tokens as f64 / tokens_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};
    use crate::scheduler::exec::{ExecMode, ParallelLda};

    #[test]
    fn plan_and_stats_agree() {
        let bow = generate(&Profile::tiny(), 41);
        let plan = partition(&bow, 4, Algorithm::A2, 41);
        let from_plan = SpeedupReport::of_plan(&plan);

        let mut lda = ParallelLda::init(&bow, &plan, 4, 0.5, 0.1, 41);
        let stats = lda.sweep(ExecMode::Sequential);
        let from_stats = SpeedupReport::of_stats(&stats, 4);

        assert_eq!(from_plan.parallel_tokens, from_stats.parallel_tokens);
        assert_eq!(from_plan.serial_tokens, from_stats.serial_tokens);
        assert!((from_plan.eta - from_stats.eta).abs() < 1e-12);
    }

    #[test]
    fn speedup_is_eta_p() {
        let bow = generate(&Profile::tiny(), 42);
        let plan = partition(&bow, 5, Algorithm::A3 { restarts: 3 }, 42);
        let r = SpeedupReport::of_plan(&plan);
        assert!((r.speedup - r.eta * 5.0).abs() < 1e-12);
        assert!(r.speedup <= 5.0 + 1e-9);
        assert!(r.speedup >= 1.0 - 1e-9); // eta ≥ 1/P always
    }

    #[test]
    fn projection_scales_with_rate() {
        let bow = generate(&Profile::tiny(), 43);
        let plan = partition(&bow, 2, Algorithm::A1, 43);
        let r = SpeedupReport::of_plan(&plan);
        let slow = r.projected_sweep_secs(1e6);
        let fast = r.projected_sweep_secs(2e6);
        assert!((slow / fast - 2.0).abs() < 1e-9);
    }
}
