//! The schedule abstraction: mapping a `P×P` partition grid onto `W`
//! executor workers.
//!
//! The paper evaluates plans as `P` diagonal epochs on exactly `P`
//! workers, which welds the grid size, the pool size, and the schedule
//! together: a `W`-core box can only run a `W×W` grid, and η is capped by
//! how well `W` coarse groups can be balanced. A [`Schedule`] breaks that
//! coupling:
//!
//! * [`ScheduleKind::Diagonal`] — the legacy mapping. `P == W`; epoch `l`
//!   hands worker `m` exactly partition `(m, (m+l) mod P)`.
//! * [`ScheduleKind::Packed`] — over-decomposition. The grid is
//!   `P = g·W` for a grid factor `g ≥ 1`; each diagonal's `P` partitions
//!   are packed onto the `W` workers longest-processing-time first, so a
//!   worker runs a *list* of partitions per epoch. The row/column
//!   non-conflict invariant is preserved for free: a diagonal's
//!   partitions are pairwise disjoint by construction, so any grouping of
//!   them onto fewer workers is still conflict-free.
//!
//! Over-decomposing strictly enlarges the space of executable schedules:
//! at `g = 1` packing degenerates to the diagonal mapping, while `g > 1`
//! lets LPT smooth per-epoch imbalance that the coarse grid cannot
//! express. The schedule-aware cost is `Σ_l max_w assigned_tokens(w, l)`
//! (the per-epoch critical path over workers), and the matching
//! load-balancing ratio uses `C_opt = N / W` — see
//! [`crate::partition::eta::eta_of_schedule`].
//!
//! Determinism: schedules only decide *which worker* samples a partition,
//! never *how* — RNG streams are keyed by `(sweep, partition)` (see
//! [`crate::scheduler::pool::task_rng`]), so any schedule over the same
//! plan produces bit-identical counts on any worker count.

use crate::partition::eta::CostMatrix;

/// Which schedule family maps the grid onto the workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// One worker per grid row: `P == W`, worker `m` runs partition
    /// `(m, (m+l) mod P)` of epoch `l` (the paper's execution model).
    Diagonal,
    /// Over-decomposed grid `P = grid_factor·W`; each diagonal is
    /// LPT-packed onto the `W` workers.
    Packed { grid_factor: usize },
}

impl ScheduleKind {
    /// Parse a CLI/config spelling; `grid_factor` applies to `packed`.
    pub fn parse(name: &str, grid_factor: usize) -> Option<Self> {
        match name {
            "diagonal" | "diag" => Some(Self::Diagonal),
            "packed" | "pack" => Some(Self::Packed { grid_factor }),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Diagonal => "diagonal",
            Self::Packed { .. } => "packed",
        }
    }

    /// Human label including the grid factor, e.g. `packed(x4)`.
    pub fn label(self) -> String {
        match self {
            Self::Diagonal => "diagonal".to_string(),
            Self::Packed { grid_factor } => format!("packed(x{grid_factor})"),
        }
    }

    /// Grid size `P` for a worker count `W`.
    pub fn grid(self, workers: usize) -> usize {
        match self {
            Self::Diagonal => workers,
            Self::Packed { grid_factor } => grid_factor * workers,
        }
    }

    pub fn grid_factor(self) -> usize {
        match self {
            Self::Diagonal => 1,
            Self::Packed { grid_factor } => grid_factor,
        }
    }
}

/// Global id of partition `(m, n)` in a `P×P` grid — the RNG keying
/// coordinate (see [`crate::scheduler::pool::task_rng`]). Stable across
/// schedules and worker counts for a fixed plan, which is exactly what
/// the cross-schedule determinism guarantee rests on.
#[inline]
pub fn partition_id(m: usize, n: usize, p: usize) -> u64 {
    (m * p + n) as u64
}

/// Identity assignment: worker `i` runs task `i` (the diagonal layout).
pub fn identity_assign(n: usize) -> Vec<Vec<u32>> {
    (0..n as u32).map(|i| vec![i]).collect()
}

/// The conflict/eligibility graph between consecutive diagonals — the
/// dependency structure the ticketed commit protocol serializes on (see
/// `docs/executor.md`, "Ticketed commit").
///
/// Position `m` of diagonal `l` is partition `(m, (m+l) mod P)`. Its
/// *conflict predecessors* are the diagonal-`(l-1)` positions touching
/// the same count rows:
///
/// * position `m` — partition `(m, (m+l-1) mod P)` shares **row** `m`
///   (the same document-count rows);
/// * position `(m+1) mod P` — partition `((m+1) mod P, (m+l) mod P)`
///   shares **column** `(m+l) mod P` (the same emission-count rows),
///   since `m' + (l-1) ≡ m + l (mod P)` solves to `m' = (m+1) mod P`.
///
/// No other diagonal-`(l-1)` position conflicts (rows and columns are
/// each hit exactly once per diagonal), so a diagonal-`l` task is
/// *eligible* as soon as these two predecessors have committed. The
/// topic-total snapshot every task samples against adds a third,
/// stronger dependency — each task reads the totals as of the end of
/// diagonal `l-1`, i.e. *all* of its tasks — which is why the executor
/// run-ahead pipelines the commit stage rather than sampling across
/// diagonals; see `docs/executor.md`.
pub fn conflict_predecessors(m: usize, p: usize) -> Vec<usize> {
    if p == 1 {
        return vec![0];
    }
    vec![m, (m + 1) % p]
}

/// One epoch's worker assignment over the diagonal's partitions.
#[derive(Clone, Debug)]
pub struct EpochPlan {
    /// `assign[w]` = diagonal positions `m` run by worker `w`; position
    /// `m` of epoch `l` is partition `(m, (m+l) mod P)`.
    pub assign: Vec<Vec<u32>>,
}

impl EpochPlan {
    /// Critical-path cost of the epoch: the max over workers of their
    /// assigned token counts, with `len(i)` giving task `i`'s tokens.
    pub fn max_assigned<F: Fn(usize) -> u64>(&self, len: F) -> u64 {
        self.assign
            .iter()
            .map(|list| list.iter().map(|&i| len(i as usize)).sum::<u64>())
            .max()
            .unwrap_or(0)
    }
}

/// A full sweep schedule: `P` epochs (one per diagonal), each assigning
/// the diagonal's `P` partitions to `W` workers.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub kind: ScheduleKind,
    /// Grid size `P` of the plan being scheduled.
    pub grid: usize,
    /// Executor worker count `W`.
    pub workers: usize,
    /// One entry per diagonal epoch, `epochs[l]`.
    pub epochs: Vec<EpochPlan>,
}

impl Schedule {
    /// Build a schedule for `costs` (a plan's `P×P` token-cost matrix)
    /// on `workers` workers. Panics if the grid is incompatible with the
    /// kind (`P != W` for diagonal, `P != g·W` for packed).
    pub fn build(kind: ScheduleKind, costs: &CostMatrix, workers: usize) -> Self {
        let p = costs.p();
        assert!(workers >= 1, "schedule needs at least one worker");
        let epochs = match kind {
            ScheduleKind::Diagonal => {
                assert_eq!(
                    p, workers,
                    "diagonal schedule needs P == W (got P={p}, W={workers})"
                );
                (0..p)
                    .map(|_| EpochPlan {
                        assign: identity_assign(p),
                    })
                    .collect()
            }
            ScheduleKind::Packed { grid_factor } => {
                assert!(grid_factor >= 1, "grid factor must be >= 1");
                assert_eq!(
                    p,
                    grid_factor * workers,
                    "packed schedule needs P == g·W (got P={p}, g={grid_factor}, W={workers})"
                );
                (0..p)
                    .map(|l| EpochPlan {
                        assign: pack_lpt(costs, l, workers),
                    })
                    .collect()
            }
        };
        Self {
            kind,
            grid: p,
            workers,
            epochs,
        }
    }

    /// Per-worker assigned token loads of epoch `l` under `costs`.
    pub fn epoch_loads(&self, costs: &CostMatrix, l: usize) -> Vec<u64> {
        let p = self.grid;
        self.epochs[l]
            .assign
            .iter()
            .map(|list| {
                list.iter()
                    .map(|&m| costs.get(m as usize, (m as usize + l) % p))
                    .sum()
            })
            .collect()
    }

    /// Schedule-aware sweep cost (the Eq. 1 analogue for `W` workers):
    /// `Σ_l max_w assigned_tokens(w, l)` — [`Self::cost_with`] under the
    /// token-cost field.
    pub fn cost(&self, costs: &CostMatrix) -> u64 {
        self.cost_with(|m, n| costs.get(m, n))
    }

    /// Re-run every diagonal's LPT packing against an arbitrary cost
    /// field `cost(m, n)` — the sweep-to-sweep re-packing hook behind
    /// [`crate::scheduler::adaptive::Measured::repack`]. The grid stays
    /// fixed; only the task→worker assignment moves, which the
    /// `(sweep, partition)` RNG keying makes result-invariant, so a
    /// trainer may repack between any two sweeps without changing
    /// trained counts. Diagonal schedules are left untouched: with one
    /// task per worker per epoch there is no packing freedom (any
    /// permutation has the same critical path).
    ///
    /// Equivalent to [`Self::repack_hetero`] with uniform worker speeds;
    /// both consult the outgoing assignment for the cache-affinity
    /// tie-break (costs tie → a partition stays with the worker that
    /// last ran it).
    pub fn repack_with(&mut self, cost: impl Fn(usize, usize) -> u64) {
        let factors = vec![1.0; self.workers];
        self.repack_hetero(cost, &factors);
    }

    /// Heterogeneity-aware re-packing: as [`Self::repack_with`], but each
    /// placement minimizes *predicted completion time*
    /// `(load_w + cost) · factor_w`, where `factors[w]` is worker `w`'s
    /// relative slowdown (1.0 = machine average — see
    /// [`crate::scheduler::adaptive::Measured::worker_factors`]). With
    /// uniform factors this is exactly classic LPT.
    pub fn repack_hetero(&mut self, cost: impl Fn(usize, usize) -> u64, factors: &[f64]) {
        if self.kind == ScheduleKind::Diagonal {
            return;
        }
        assert_eq!(factors.len(), self.workers, "one speed factor per worker");
        let p = self.grid;
        let w = self.workers;
        for (l, ep) in self.epochs.iter_mut().enumerate() {
            ep.assign = pack_lpt_hetero(p, w, l, &cost, factors, Some(&ep.assign));
        }
    }

    /// Critical path of the schedule under an arbitrary cost field:
    /// `Σ_l max_w Σ_{tasks of w} cost(m, n)`. The objective
    /// [`Self::repack_with`] packs against; [`Self::cost`] is the
    /// token-count special case.
    pub fn cost_with(&self, cost: impl Fn(usize, usize) -> u64) -> u64 {
        let p = self.grid;
        self.epochs
            .iter()
            .enumerate()
            .map(|(l, ep)| {
                ep.assign
                    .iter()
                    .map(|list| {
                        list.iter()
                            .map(|&m| cost(m as usize, (m as usize + l) % p))
                            .sum::<u64>()
                    })
                    .max()
                    .unwrap_or(0)
            })
            .sum()
    }
}

/// Longest-processing-time-first packing of diagonal `l`'s `P` partitions
/// onto `workers` bins under the token-cost matrix.
fn pack_lpt(costs: &CostMatrix, l: usize, workers: usize) -> Vec<Vec<u32>> {
    pack_lpt_by(costs.p(), workers, l, |m, n| costs.get(m, n))
}

/// LPT packing of diagonal `l`'s `p` partitions onto `workers` bins under
/// an arbitrary cost field `cost(m, n)`: walk the partitions in
/// descending cost order and give each to the currently lightest worker.
/// Ties break toward the lower diagonal position / lower worker index, so
/// the packing is a pure function of the cost field.
pub fn pack_lpt_by(
    p: usize,
    workers: usize,
    l: usize,
    cost: impl Fn(usize, usize) -> u64,
) -> Vec<Vec<u32>> {
    let factors = vec![1.0; workers];
    pack_lpt_hetero(p, workers, l, cost, &factors, None)
}

/// The general LPT packer behind [`pack_lpt_by`] and
/// [`Schedule::repack_hetero`]: heterogeneous workers and cache-affinity
/// tie-breaks.
///
/// Partitions are placed in descending cost order (ties toward the lower
/// diagonal position); each goes to the worker minimizing its predicted
/// completion time `(load_w + cost) · factors[w]`. On an exact tie the
/// partition's previous owner in `prev` wins (keeping it on the worker
/// whose cache lines still hold its rows), then the lower worker index —
/// so the packing stays a pure function of `(cost, factors, prev)`.
/// Uniform factors make completion-time minimization coincide with
/// classic least-loaded LPT, and `prev = None` reproduces the historical
/// lowest-index tie-break exactly.
pub fn pack_lpt_hetero(
    p: usize,
    workers: usize,
    l: usize,
    cost: impl Fn(usize, usize) -> u64,
    factors: &[f64],
    prev: Option<&[Vec<u32>]>,
) -> Vec<Vec<u32>> {
    assert_eq!(factors.len(), workers, "one speed factor per worker");
    // Previous owner of each diagonal position, for the affinity
    // tie-break (usize::MAX = none).
    let mut owner = vec![usize::MAX; p];
    if let Some(prev) = prev {
        for (w, list) in prev.iter().enumerate() {
            for &m in list {
                if (m as usize) < p {
                    owner[m as usize] = w;
                }
            }
        }
    }
    let mut order: Vec<u32> = (0..p as u32).collect();
    order.sort_by(|&a, &b| {
        let ca = cost(a as usize, (a as usize + l) % p);
        let cb = cost(b as usize, (b as usize + l) % p);
        cb.cmp(&ca).then(a.cmp(&b))
    });
    let mut assign: Vec<Vec<u32>> = vec![Vec::new(); workers];
    let mut loads = vec![0f64; workers];
    for m in order {
        let c = cost(m as usize, (m as usize + l) % p) as f64;
        let mut best = 0usize;
        let mut best_key = f64::INFINITY;
        for (w, (&load, &factor)) in loads.iter().zip(factors).enumerate() {
            let key = (load + c) * factor;
            // Strict `<` keeps the first (lowest-index) minimizer; the
            // equality arm lets the previous owner displace it on ties.
            if key < best_key || (key == best_key && owner[m as usize] == w) {
                best = w;
                best_key = key;
            }
        }
        assign[best].push(m);
        loads[best] += c;
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::bow::BagOfWords;
    use crate::partition::{partition, Algorithm};
    use crate::testing::prop;

    fn costs_of(bow: &BagOfWords, p: usize, seed: u64) -> CostMatrix {
        let plan = partition(bow, p, Algorithm::A3 { restarts: 2 }, seed);
        plan.costs
    }

    fn small_bow(seed: u64) -> BagOfWords {
        crate::corpus::synthetic::generate(
            &crate::corpus::synthetic::Profile::tiny(),
            seed,
        )
    }

    #[test]
    fn diagonal_is_identity() {
        let bow = small_bow(1);
        let costs = costs_of(&bow, 4, 1);
        let s = Schedule::build(ScheduleKind::Diagonal, &costs, 4);
        assert_eq!(s.grid, 4);
        assert_eq!(s.workers, 4);
        assert_eq!(s.epochs.len(), 4);
        for ep in &s.epochs {
            for (w, list) in ep.assign.iter().enumerate() {
                assert_eq!(list.as_slice(), &[w as u32]);
            }
        }
        // Diagonal schedule cost is exactly the plan's Eq. 1 cost.
        assert_eq!(s.cost(&costs), costs.sweep_cost());
    }

    #[test]
    fn packed_g1_has_diagonal_cost() {
        // With one task per worker, LPT can only permute the worker
        // assignment — the critical path is the diagonal max either way.
        let bow = small_bow(2);
        let costs = costs_of(&bow, 6, 2);
        let s = Schedule::build(ScheduleKind::Packed { grid_factor: 1 }, &costs, 6);
        assert_eq!(s.cost(&costs), costs.sweep_cost());
    }

    #[test]
    fn packed_epoch_loads_are_consistent() {
        // Internal consistency of the packing: per-epoch worker loads
        // conserve the diagonal's tokens, and the critical path can
        // never undercut the mean load.
        let bow = small_bow(3);
        let w = 3;
        for g in [1usize, 2, 4] {
            let costs = costs_of(&bow, g * w, 3);
            let s = Schedule::build(ScheduleKind::Packed { grid_factor: g }, &costs, w);
            for l in 0..s.grid {
                let loads = s.epoch_loads(&costs, l);
                assert_eq!(loads.len(), w);
                let total: u64 = loads.iter().sum();
                let max = *loads.iter().max().unwrap();
                assert_eq!(total, costs.diagonal_sum(l));
                assert!(max as f64 >= total as f64 / w as f64 - 1e-9);
            }
        }
    }

    #[test]
    fn lpt_beats_naive_folding_on_skewed_diagonals() {
        // One heavy partition per diagonal: LPT must isolate it rather
        // than stack it with others. Build a 4×4 grid over 2 workers.
        let bow = BagOfWords::from_triplets(
            4,
            4,
            [
                (0, 0, 100),
                (1, 1, 1),
                (2, 2, 1),
                (3, 3, 1),
                (0, 1, 50),
                (1, 2, 2),
                (2, 3, 2),
                (3, 0, 2),
            ],
        );
        let costs = CostMatrix::compute_p(&bow, &[0, 1, 2, 3], &[0, 1, 2, 3], 4);
        let s = Schedule::build(ScheduleKind::Packed { grid_factor: 2 }, &costs, 2);
        // Epoch 0 has costs {100, 1, 1, 1}: LPT puts 100 alone, so the
        // critical path is 100, not 101+.
        let loads = s.epoch_loads(&costs, 0);
        assert_eq!(*loads.iter().max().unwrap(), 100);
    }

    #[test]
    fn schedule_kind_parses_and_sizes() {
        assert_eq!(ScheduleKind::parse("diagonal", 1), Some(ScheduleKind::Diagonal));
        assert_eq!(ScheduleKind::parse("diag", 1), Some(ScheduleKind::Diagonal));
        assert_eq!(
            ScheduleKind::parse("packed", 4),
            Some(ScheduleKind::Packed { grid_factor: 4 })
        );
        assert_eq!(ScheduleKind::parse("lpt", 1), None);
        assert_eq!(ScheduleKind::Diagonal.grid(8), 8);
        assert_eq!(ScheduleKind::Packed { grid_factor: 4 }.grid(8), 32);
        assert_eq!(ScheduleKind::Packed { grid_factor: 2 }.label(), "packed(x2)");
        assert_eq!(ScheduleKind::Diagonal.grid_factor(), 1);
    }

    #[test]
    fn repack_with_token_costs_is_a_fixed_point() {
        // Repacking against the same token-cost field LPT already packed
        // against must reproduce the assignment exactly (LPT is a pure
        // function of the cost field).
        let bow = small_bow(7);
        let costs = costs_of(&bow, 8, 7);
        let s0 = Schedule::build(ScheduleKind::Packed { grid_factor: 4 }, &costs, 2);
        let mut s1 = s0.clone();
        s1.repack_with(|m, n| costs.get(m, n));
        for (a, b) in s0.epochs.iter().zip(s1.epochs.iter()) {
            assert_eq!(a.assign, b.assign);
        }
    }

    #[test]
    fn repack_with_skewed_costs_moves_the_assignment_and_cuts_the_crit() {
        // Token counts say diagonal 0 is {100, 1, 1, 1}; pretend the
        // measured field inverts it ({1, 900, 900, 900} ns). Repacking
        // must rebalance against the measured field, and the repacked
        // critical path under that field can never exceed the stale
        // token packing's.
        let bow = BagOfWords::from_triplets(
            4,
            4,
            [
                (0, 0, 100),
                (1, 1, 1),
                (2, 2, 1),
                (3, 3, 1),
                (0, 1, 50),
                (1, 2, 2),
                (2, 3, 2),
                (3, 0, 2),
            ],
        );
        let costs = CostMatrix::compute_p(&bow, &[0, 1, 2, 3], &[0, 1, 2, 3], 4);
        let mut s = Schedule::build(ScheduleKind::Packed { grid_factor: 2 }, &costs, 2);
        let measured = |m: usize, _n: usize| if m == 0 { 1 } else { 900 };
        let before = s.cost_with(measured);
        s.repack_with(measured);
        let after = s.cost_with(measured);
        assert!(after <= before, "repack regressed the measured crit: {after} > {before}");
        // Diagonal 0 under the measured field is {1, 900, 900, 900} on 2
        // workers: LPT packs {900, 1} vs {900, 900} → crit 1800.
        let crit0: u64 = s.epochs[0]
            .assign
            .iter()
            .map(|list| list.iter().map(|&m| measured(m as usize, m as usize)).sum::<u64>())
            .max()
            .unwrap();
        assert_eq!(crit0, 1800);
    }

    #[test]
    fn repack_is_a_noop_for_diagonal_schedules() {
        let bow = small_bow(8);
        let costs = costs_of(&bow, 4, 8);
        let mut s = Schedule::build(ScheduleKind::Diagonal, &costs, 4);
        s.repack_with(|_, _| 77);
        for ep in &s.epochs {
            for (w, list) in ep.assign.iter().enumerate() {
                assert_eq!(list.as_slice(), &[w as u32]);
            }
        }
    }

    #[test]
    fn affinity_tie_break_keeps_partitions_on_their_last_worker() {
        // A diagonal whose partitions all cost the same has total packing
        // freedom; the tie-break must keep each partition with the worker
        // that last ran it instead of reshuffling by index. Build a 4×4
        // grid with every cell equal (all diagonals fully tied).
        let mut cells = Vec::new();
        for m in 0..4u32 {
            for n in 0..4u32 {
                cells.push((m, n, 10u32));
            }
        }
        let bow = BagOfWords::from_triplets(4, 4, cells);
        let costs = CostMatrix::compute_p(&bow, &[0, 1, 2, 3], &[0, 1, 2, 3], 4);
        let mut s = Schedule::build(ScheduleKind::Packed { grid_factor: 2 }, &costs, 2);
        // Hand-pin an assignment LPT-by-index would never produce, then
        // repack against the same (tied) cost field: affinity must keep
        // every partition with its pinned owner.
        for ep in &mut s.epochs {
            ep.assign = vec![vec![1, 2], vec![0, 3]];
        }
        s.repack_with(|m, n| costs.get(m, n));
        for (l, ep) in s.epochs.iter().enumerate() {
            let mut w0 = ep.assign[0].clone();
            let mut w1 = ep.assign[1].clone();
            w0.sort_unstable();
            w1.sort_unstable();
            assert_eq!(w0, vec![1, 2], "epoch {l}: worker 0 kept its partitions");
            assert_eq!(w1, vec![0, 3], "epoch {l}: worker 1 kept its partitions");
        }
    }

    #[test]
    fn hetero_packing_shifts_load_toward_fast_workers() {
        // Four equal-cost tasks on 2 workers whose measured speeds differ
        // 3×: completion-time LPT must give the fast worker three tasks
        // and the slow worker one (completion 3c·0.5 = 1c·1.5).
        let assign = pack_lpt_hetero(4, 2, 0, |_, _| 100, &[0.5, 1.5], None);
        assert_eq!(assign[0].len(), 3, "fast worker absorbs the load: {assign:?}");
        assert_eq!(assign[1].len(), 1, "slow worker gets one task: {assign:?}");
    }

    #[test]
    fn hetero_packing_with_uniform_factors_matches_classic_lpt() {
        let bow = small_bow(10);
        let costs = costs_of(&bow, 8, 10);
        for l in 0..8 {
            let classic = pack_lpt_by(8, 2, l, |m, n| costs.get(m, n));
            let hetero =
                pack_lpt_hetero(8, 2, l, |m, n| costs.get(m, n), &[1.0, 1.0], None);
            assert_eq!(classic, hetero, "diagonal {l}");
        }
    }

    #[test]
    fn cost_with_tokens_matches_cost() {
        let bow = small_bow(9);
        let costs = costs_of(&bow, 6, 9);
        let s = Schedule::build(ScheduleKind::Packed { grid_factor: 3 }, &costs, 2);
        assert_eq!(s.cost(&costs), s.cost_with(|m, n| costs.get(m, n)));
    }

    /// The satellite property: for random corpora, `W`, and `g`, the
    /// packed schedule covers every partition exactly once per sweep and
    /// never co-schedules two partitions sharing a row or column group.
    #[test]
    fn packed_schedule_covers_all_partitions_conflict_free() {
        prop::check("packed-cover-nonconflict", 0x5C4ED, 48, |rng| {
            let w = 1 + rng.gen_range(4);
            let g = 1 + rng.gen_range(4);
            let p = g * w;
            let bow = prop::gen_bow(rng, 40, 40);
            let plan = partition(&bow, p, Algorithm::A3 { restarts: 1 }, rng.next_u64());
            let s = Schedule::build(
                ScheduleKind::Packed { grid_factor: g },
                &plan.costs,
                w,
            );
            let mut seen = vec![false; p * p];
            for (l, ep) in s.epochs.iter().enumerate() {
                let mut rows = vec![false; p];
                let mut cols = vec![false; p];
                assert_eq!(ep.assign.len(), w);
                for list in &ep.assign {
                    for &m in list {
                        let m = m as usize;
                        let n = (m + l) % p;
                        assert!(!seen[m * p + n], "partition scheduled twice");
                        seen[m * p + n] = true;
                        assert!(
                            !rows[m] && !cols[n],
                            "co-scheduled partitions share a row/column group"
                        );
                        rows[m] = true;
                        cols[n] = true;
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "some partition never scheduled");
        });
    }

    /// Eligibility-graph unit test: the conflict predecessors of every
    /// diagonal-`l` position are exactly the diagonal-`(l-1)` positions
    /// sharing a row or column with it — no in-flight pair within a
    /// diagonal ever conflicts, and nothing outside the predecessor set
    /// does either.
    #[test]
    fn conflict_predecessors_are_exactly_the_row_column_sharers() {
        for p in [1usize, 2, 3, 4, 7, 8] {
            for l in 0..p {
                for m in 0..p {
                    let n = (m + l) % p;
                    let preds = conflict_predecessors(m, p);
                    assert!(!preds.is_empty());
                    for m2 in 0..p {
                        // Diagonal l-1 position m2 = partition
                        // (m2, (m2 + l - 1) mod p).
                        let n2 = (m2 + l + p - 1) % p;
                        let conflicts = m2 == m || n2 == n;
                        assert_eq!(
                            preds.contains(&m2),
                            conflicts,
                            "p={p} l={l}: diag-l pos {m} vs diag-(l-1) pos {m2}"
                        );
                    }
                    // Within the same diagonal nothing conflicts: every
                    // other position has a different row and column.
                    for m2 in 0..p {
                        if m2 != m {
                            assert_ne!((m2 + l) % p, n, "in-flight tasks share a column");
                        }
                    }
                }
            }
        }
    }

    /// Property form over random packed schedules: tasks in flight
    /// together (same diagonal, any worker grouping) never share a row
    /// or column, and each task's predecessor set covers every
    /// row/column sharer in the previous diagonal.
    #[test]
    fn eligibility_graph_holds_on_random_schedules() {
        prop::check("eligibility-graph", 0x71C4E7, 24, |rng| {
            let w = 1 + rng.gen_range(4);
            let g = 1 + rng.gen_range(3);
            let p = g * w;
            let bow = prop::gen_bow(rng, 30, 30);
            let plan = partition(&bow, p, Algorithm::A3 { restarts: 1 }, rng.next_u64());
            let s = Schedule::build(ScheduleKind::Packed { grid_factor: g }, &plan.costs, w);
            for (l, ep) in s.epochs.iter().enumerate() {
                let mut rows = vec![false; p];
                let mut cols = vec![false; p];
                for list in &ep.assign {
                    for &m in list {
                        let m = m as usize;
                        let n = (m + l) % p;
                        assert!(!rows[m] && !cols[n], "in-flight conflict at epoch {l}");
                        rows[m] = true;
                        cols[n] = true;
                        if l > 0 {
                            for m2 in 0..p {
                                let n2 = (m2 + l - 1) % p;
                                if m2 == m || n2 == n {
                                    assert!(
                                        conflict_predecessors(m, p).contains(&m2),
                                        "missed predecessor {m2} of (l={l}, m={m})"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        });
    }
}
