//! Shared row-major count matrices with partition-scoped exclusive rows.
//!
//! Within one diagonal epoch, worker `m` samples partition
//! `(m, (m+l) mod P)` and therefore touches only document rows in group
//! `J_m` and word rows in group `V_{(m+l) mod P}`. Row groups are
//! pairwise disjoint within an epoch (see
//! [`crate::partition::scheme::PartitionMap::diagonal`] tests), so
//! handing every worker a raw pointer into the same matrix is race-free
//! *provided each worker only dereferences rows of its own groups* — the
//! invariant the sampling kernel upholds by construction (its tokens all
//! lie inside the partition).

use std::marker::PhantomData;

/// A `rows × k` f32 count matrix shared across epoch workers.
#[derive(Clone, Copy)]
pub struct SharedRows<'a> {
    ptr: *mut f32,
    rows: usize,
    k: usize,
    _marker: PhantomData<&'a mut [f32]>,
}

// SAFETY: access is partitioned by row groups that are disjoint within an
// epoch; the barrier between epochs sequences cross-epoch accesses.
unsafe impl Send for SharedRows<'_> {}
unsafe impl Sync for SharedRows<'_> {}

impl<'a> SharedRows<'a> {
    pub fn new(data: &'a mut [f32], k: usize) -> Self {
        assert!(k > 0);
        assert_eq!(data.len() % k, 0, "matrix not a multiple of k");
        Self {
            ptr: data.as_mut_ptr(),
            rows: data.len() / k,
            k,
            _marker: PhantomData,
        }
    }

    /// Rebuild a view from raw parts — the receiving side of a
    /// lifetime-erased transfer (see [`crate::scheduler::pool`]'s `Job`).
    ///
    /// # Safety
    /// `ptr` must point to a live `rows × k` f32 matrix for as long as
    /// the view is used, under the same row-ownership discipline as
    /// [`Self::row_ptr`] (the caller chooses `'a`; it must not outlive
    /// the backing allocation's borrow).
    pub unsafe fn from_raw(ptr: *mut f32, rows: usize, k: usize) -> Self {
        debug_assert!(k > 0);
        Self {
            ptr,
            rows,
            k,
            _marker: PhantomData,
        }
    }

    /// Raw pointer to the start of `row`.
    ///
    /// # Safety
    /// The caller must hold exclusive logical ownership of `row` for the
    /// current epoch (diagonal non-conflict invariant).
    #[inline]
    pub unsafe fn row_ptr(&self, row: usize) -> *mut f32 {
        debug_assert!(row < self.rows, "row {row} out of {}", self.rows);
        self.ptr.add(row * self.k)
    }

    /// Base pointer of the matrix (row 0). Used by the worker pool to
    /// ship a lifetime-erased view to long-lived workers; the same row
    /// ownership rules as [`Self::row_ptr`] apply to any access through
    /// it.
    #[inline]
    pub fn base_ptr(&self) -> *mut f32 {
        self.ptr
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_ptr_addresses_rows() {
        let mut data = vec![0f32; 12];
        let m = SharedRows::new(&mut data, 3);
        assert_eq!(m.rows(), 4);
        unsafe {
            *m.row_ptr(2) = 7.0;
            *m.row_ptr(2).add(2) = 9.0;
        }
        assert_eq!(data[6], 7.0);
        assert_eq!(data[8], 9.0);
    }

    #[test]
    fn disjoint_rows_from_threads() {
        let mut data = vec![0f32; 8 * 4];
        let m = SharedRows::new(&mut data, 4);
        std::thread::scope(|s| {
            for w in 0..4usize {
                let m = m;
                s.spawn(move || {
                    // Worker w exclusively owns rows {w, w+4}.
                    for &row in &[w, w + 4] {
                        unsafe {
                            let p = m.row_ptr(row);
                            for i in 0..4 {
                                *p.add(i) = (row * 10 + i) as f32;
                            }
                        }
                    }
                });
            }
        });
        for row in 0..8 {
            for i in 0..4 {
                assert_eq!(data[row * 4 + i], (row * 10 + i) as f32);
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiple of k")]
    fn bad_shape_panics() {
        let mut data = vec![0f32; 7];
        SharedRows::new(&mut data, 3);
    }
}
