//! Row/column orderings implementing the paper's three heuristics.
//!
//! Each function takes item weights (row workloads `RR_j` or column
//! workloads `CR_w`) and returns an *ordering* — a permutation of item
//! ids — which [`crate::partition::split`] then cuts into `P` consecutive
//! groups of approximately equal token mass.

use crate::util::rng::Rng;

/// Item ids sorted by weight, descending (ties by id for determinism).
pub fn sorted_desc(weights: &[u64]) -> Vec<u32> {
    let mut ids: Vec<u32> = (0..weights.len() as u32).collect();
    ids.sort_by(|&a, &b| {
        weights[b as usize]
            .cmp(&weights[a as usize])
            .then(a.cmp(&b))
    });
    ids
}

/// Heuristic 1 (Algorithm A1): interpose long and short items from the
/// *front*: `[L1, S1, L2, S2, …, median]`.
pub fn interpose_front(weights: &[u64]) -> Vec<u32> {
    let sorted = sorted_desc(weights);
    let n = sorted.len();
    let mut out = Vec::with_capacity(n);
    let (mut lo, mut hi) = (0usize, n);
    // Alternate: longest remaining, then shortest remaining.
    while lo < hi {
        out.push(sorted[lo]);
        lo += 1;
        if lo < hi {
            hi -= 1;
            out.push(sorted[hi]);
        }
    }
    out
}

/// Heuristic 2 (Algorithm A2): sort descending, then swap even 1-based
/// positions `i < n/2` with their mirror `n+1-i`, interposing long and
/// short from *both ends* of the list.
pub fn interpose_both_ends(weights: &[u64]) -> Vec<u32> {
    let mut out = sorted_desc(weights);
    let n = out.len();
    // Paper Algorithm 2, 1-based: for i in 1..n/2, if i mod 2 == 0,
    // swap RR_i with RR_{n+1-i}.
    let mut i = 2usize;
    while i < n / 2 {
        out.swap(i - 1, n - i);
        i += 2;
    }
    out
}

/// Heuristic 3 core (one randomized draw of Algorithm A3): sort
/// descending, slice into strata of `p` consecutive items, deal one item
/// of each stratum to each of `p` buckets (uniformly within the stratum),
/// shuffle each bucket, concatenate. Every window of the result then
/// contains items of all length classes.
pub fn stratified_shuffle(weights: &[u64], p: usize, rng: &mut Rng) -> Vec<u32> {
    assert!(p >= 1);
    let sorted = sorted_desc(weights);
    let n = sorted.len();
    let mut buckets: Vec<Vec<u32>> = vec![Vec::with_capacity(n / p + 1); p];

    let mut stratum = Vec::with_capacity(p);
    for chunk in sorted.chunks(p) {
        stratum.clear();
        stratum.extend_from_slice(chunk);
        rng.shuffle(&mut stratum);
        for (i, &item) in stratum.iter().enumerate() {
            buckets[i].push(item);
        }
    }
    let mut out = Vec::with_capacity(n);
    for bucket in &mut buckets {
        rng.shuffle(bucket);
        out.extend_from_slice(bucket);
    }
    out
}

/// Baseline (Yan et al.): uniform random permutation.
pub fn uniform_shuffle(n: usize, rng: &mut Rng) -> Vec<u32> {
    let mut out: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut out);
    out
}

fn is_permutation(order: &[u32], n: usize) -> bool {
    if order.len() != n {
        return false;
    }
    let mut seen = vec![false; n];
    for &i in order {
        if (i as usize) >= n || seen[i as usize] {
            return false;
        }
        seen[i as usize] = true;
    }
    true
}

/// Debug-check helper exposed for property tests.
pub fn assert_permutation(order: &[u32], n: usize) {
    assert!(is_permutation(order, n), "not a permutation of 0..{n}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn sorted_desc_orders() {
        let w = [3u64, 9, 1, 9];
        assert_eq!(sorted_desc(&w), vec![1, 3, 0, 2]); // ties by id
    }

    #[test]
    fn interpose_front_pattern() {
        // weights: ids 0..6 with weight = id → sorted desc [5,4,3,2,1,0]
        let w: Vec<u64> = (0..6).collect();
        // L1,S1,L2,S2,L3,S3 = 5,0,4,1,3,2
        assert_eq!(interpose_front(&w), vec![5, 0, 4, 1, 3, 2]);
    }

    #[test]
    fn interpose_front_odd_length() {
        let w: Vec<u64> = (0..5).collect(); // sorted desc [4,3,2,1,0]
        assert_eq!(interpose_front(&w), vec![4, 0, 3, 1, 2]);
    }

    #[test]
    fn interpose_both_ends_pattern() {
        // n=8, sorted desc ids = [7,6,5,4,3,2,1,0].
        // 1-based even i < 4: i=2 → swap positions 2 and 7 (1-based).
        let w: Vec<u64> = (0..8).collect();
        assert_eq!(interpose_both_ends(&w), vec![7, 1, 5, 4, 3, 2, 6, 0]);
    }

    #[test]
    fn all_orderings_are_permutations() {
        prop::check("orderings-are-permutations", 0xA11, 48, |rng| {
            let n = prop::gen_size(rng, 1, 500);
            let w = prop::gen_heavy_tailed(rng, n, 10_000)
                .into_iter()
                .map(u64::from)
                .collect::<Vec<_>>();
            let p = 1 + rng.gen_range(16);
            assert_permutation(&interpose_front(&w), n);
            assert_permutation(&interpose_both_ends(&w), n);
            assert_permutation(&stratified_shuffle(&w, p, rng), n);
            assert_permutation(&uniform_shuffle(n, rng), n);
        });
    }

    #[test]
    fn stratified_distributes_length_classes() {
        // After stratified shuffle with p buckets, each contiguous n/p
        // window must contain one item from (almost) every stratum, so
        // window mass is near-uniform — unlike the sorted order.
        let mut rng = crate::util::rng::Rng::new(77);
        let n = 400;
        let p = 8;
        let w: Vec<u64> = (0..n as u64).map(|i| (i + 1) * (i + 1)).collect();
        let order = stratified_shuffle(&w, p, &mut rng);
        let window = n / p;
        let masses: Vec<u64> = (0..p)
            .map(|b| {
                order[b * window..(b + 1) * window]
                    .iter()
                    .map(|&i| w[i as usize])
                    .sum()
            })
            .collect();
        let max = *masses.iter().max().unwrap() as f64;
        let min = *masses.iter().min().unwrap() as f64;
        assert!(
            max / min < 1.25,
            "stratified windows should be near-uniform: {masses:?}"
        );
    }

    #[test]
    fn empty_input_ok() {
        assert!(interpose_front(&[]).is_empty());
        assert!(interpose_both_ends(&[]).is_empty());
        let mut rng = crate::util::rng::Rng::new(1);
        assert!(stratified_shuffle(&[], 4, &mut rng).is_empty());
    }
}
