//! The paper's contribution: partitioning algorithms for the `P×P`
//! decomposition of the document–word matrix (§III–IV).
//!
//! A partitioning assigns every document to one of `P` row groups
//! `J_1..J_P` and every word to one of `P` column groups `V_1..V_P`;
//! partition `DW_mn` holds the cells of `(J_m, V_n)`. Diagonal `l`
//! contains the partitions `(m, (m+l) mod P)`, which are pairwise
//! non-conflicting and are sampled in parallel. The per-sweep cost is
//! `C = Σ_l max_m C_{m,(m+l) mod P}` and the load-balancing ratio is
//! `η = C_opt / C` with `C_opt = N / P` (Eq. 1–2).
//!
//! Four algorithms are provided:
//!
//! * [`Algorithm::Baseline`] — Yan et al.'s randomized shuffle,
//!   restart-and-keep-best.
//! * [`Algorithm::A1`] — deterministic; interpose long/short from the
//!   front of the sorted list (Heuristic 1).
//! * [`Algorithm::A2`] — deterministic; interpose long/short from both
//!   ends (Heuristic 2).
//! * [`Algorithm::A3`] — stratified randomized shuffle (Heuristic 3),
//!   restart-and-keep-best; guaranteed no worse than its own first
//!   restart and empirically the best η of the four.

pub mod algorithms;
pub mod eta;
pub mod permutation;
pub mod scheme;
pub mod split;
pub mod variants;

use crate::corpus::bow::BagOfWords;
use crate::util::rng::Rng;

pub use eta::{CostMatrix, EtaComparison, EtaReport};
pub use scheme::PartitionMap;

/// Which partitioning algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Yan et al. baseline: uniform random row/column shuffles, keep the
    /// best of `restarts` candidates.
    Baseline { restarts: usize },
    /// Deterministic Heuristic-1 interposition (paper Algorithm 1).
    A1,
    /// Deterministic Heuristic-2 interposition (paper Algorithm 2).
    A2,
    /// Stratified randomized permutation (paper Algorithm 3), keep the
    /// best of `restarts` candidates.
    A3 { restarts: usize },
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Baseline { .. } => "baseline",
            Algorithm::A1 => "A1",
            Algorithm::A2 => "A2",
            Algorithm::A3 { .. } => "A3",
        }
    }

    pub fn is_deterministic(&self) -> bool {
        matches!(self, Algorithm::A1 | Algorithm::A2)
    }
}

/// Result of a partitioning run: the group assignment plus its quality.
#[derive(Clone, Debug)]
pub struct Plan {
    pub p: usize,
    /// Row group of each document (`0..p`).
    pub doc_group: Vec<u32>,
    /// Column group of each word (`0..p`).
    pub word_group: Vec<u32>,
    /// Load-balancing ratio `η = C_opt / C` (Eq. 2).
    pub eta: f64,
    /// Epoch-sum cost `C` (Eq. 1), in tokens.
    pub cost: f64,
    /// Full `P×P` cost matrix (tokens per partition).
    pub costs: CostMatrix,
    /// Algorithm that produced the plan.
    pub algorithm: &'static str,
}

impl Plan {
    /// Documents of each row group, derived from `doc_group`.
    pub fn doc_groups(&self) -> Vec<Vec<u32>> {
        group_lists(&self.doc_group, self.p)
    }

    /// Words of each column group.
    pub fn word_groups(&self) -> Vec<Vec<u32>> {
        group_lists(&self.word_group, self.p)
    }
}

pub(crate) fn group_lists(assignment: &[u32], p: usize) -> Vec<Vec<u32>> {
    let mut out = vec![Vec::new(); p];
    for (i, &g) in assignment.iter().enumerate() {
        out[g as usize].push(i as u32);
    }
    out
}

/// Run `algo` on the workload matrix of `bow` and return the best plan
/// found. Deterministic algorithms ignore `seed`. The randomized
/// algorithms' repeated draws fan out over [`default_draw_threads`]
/// OS threads; results are identical at any thread count (each draw's
/// RNG stream is keyed by its index, and the reduction is
/// order-independent — see [`algorithms::best_plan_parallel`]).
pub fn partition(bow: &BagOfWords, p: usize, algo: Algorithm, seed: u64) -> Plan {
    let restarts = match algo {
        Algorithm::A3 { restarts } | Algorithm::Baseline { restarts } => restarts,
        _ => 1,
    };
    partition_threaded(bow, p, algo, seed, default_draw_threads(restarts))
}

/// As [`partition`], with an explicit draw-thread count for the
/// randomized algorithms (`1` = the serial reference; the bench compares
/// the two). Deterministic algorithms ignore it.
pub fn partition_threaded(
    bow: &BagOfWords,
    p: usize,
    algo: Algorithm,
    seed: u64,
    threads: usize,
) -> Plan {
    assert!(p >= 1, "P must be >= 1");
    match algo {
        Algorithm::A1 => algorithms::run_a1(bow, p),
        Algorithm::A2 => algorithms::run_a2(bow, p),
        Algorithm::A3 { restarts } => {
            algorithms::best_plan_parallel(restarts, threads, |t| {
                let mut rng = Rng::stream(seed, t as u64);
                algorithms::run_a3_once(bow, p, &mut rng)
            })
        }
        Algorithm::Baseline { restarts } => {
            algorithms::best_plan_parallel(restarts, threads, |t| {
                let mut rng = Rng::stream(seed ^ 0xBA5E, t as u64);
                algorithms::run_baseline_once(bow, p, &mut rng)
            })
        }
    }
}

/// Draw-thread count for a restart budget: the machine's parallelism,
/// but never more than a quarter of the draws (tiny budgets aren't worth
/// the spawns — each thread should amortize its spawn over several
/// draws), capped at 8.
pub fn default_draw_threads(restarts: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    cores.min(restarts / 4).clamp(1, 8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};

    fn tiny() -> BagOfWords {
        generate(&Profile::tiny(), 42)
    }

    #[test]
    fn p1_is_perfectly_balanced() {
        let bow = tiny();
        for algo in [
            Algorithm::Baseline { restarts: 2 },
            Algorithm::A1,
            Algorithm::A2,
            Algorithm::A3 { restarts: 2 },
        ] {
            let plan = partition(&bow, 1, algo, 1);
            assert!((plan.eta - 1.0).abs() < 1e-12, "{}: {}", algo.name(), plan.eta);
        }
    }

    #[test]
    fn partitions_are_disjoint_and_exhaustive() {
        let bow = tiny();
        for algo in [
            Algorithm::Baseline { restarts: 2 },
            Algorithm::A1,
            Algorithm::A2,
            Algorithm::A3 { restarts: 2 },
        ] {
            let plan = partition(&bow, 4, algo, 7);
            assert_eq!(plan.doc_group.len(), bow.num_docs());
            assert_eq!(plan.word_group.len(), bow.num_words());
            assert!(plan.doc_group.iter().all(|&g| (g as usize) < 4));
            assert!(plan.word_group.iter().all(|&g| (g as usize) < 4));
            let total: u64 = plan.doc_groups().iter().map(|g| g.len() as u64).sum();
            assert_eq!(total, bow.num_docs() as u64);
        }
    }

    #[test]
    fn eta_in_unit_interval() {
        let bow = tiny();
        for p in [2, 3, 5, 8] {
            for algo in [
                Algorithm::Baseline { restarts: 3 },
                Algorithm::A1,
                Algorithm::A2,
                Algorithm::A3 { restarts: 3 },
            ] {
                let plan = partition(&bow, p, algo, 3);
                assert!(
                    plan.eta > 0.0 && plan.eta <= 1.0 + 1e-12,
                    "{} P={p}: eta={}",
                    algo.name(),
                    plan.eta
                );
            }
        }
    }

    #[test]
    fn deterministic_algorithms_reproduce() {
        let bow = tiny();
        let a = partition(&bow, 6, Algorithm::A1, 1);
        let b = partition(&bow, 6, Algorithm::A1, 999);
        assert_eq!(a.doc_group, b.doc_group);
        assert_eq!(a.word_group, b.word_group);
        let a = partition(&bow, 6, Algorithm::A2, 1);
        let b = partition(&bow, 6, Algorithm::A2, 999);
        assert_eq!(a.doc_group, b.doc_group);
    }

    #[test]
    fn parallel_draws_equal_serial_for_any_thread_count() {
        // The satellite guarantee: the randomized algorithms' fan-out
        // cannot change the chosen plan — draws are keyed by index and
        // the reduction is order-independent.
        let bow = generate(&Profile::tiny(), 21);
        for algo in [Algorithm::A3 { restarts: 9 }, Algorithm::Baseline { restarts: 9 }] {
            let serial = partition_threaded(&bow, 5, algo, 77, 1);
            for threads in [2usize, 3, 8, 64] {
                let par = partition_threaded(&bow, 5, algo, 77, threads);
                assert_eq!(serial.doc_group, par.doc_group, "{} x{threads}", algo.name());
                assert_eq!(serial.word_group, par.word_group, "{} x{threads}", algo.name());
                assert_eq!(serial.eta, par.eta, "{} x{threads}", algo.name());
            }
        }
    }

    #[test]
    fn default_draw_threads_scales_with_budget() {
        assert_eq!(default_draw_threads(1), 1);
        assert_eq!(default_draw_threads(3), 1, "tiny budgets stay serial");
        let t = default_draw_threads(100);
        assert!(t >= 1 && t <= 8);
        assert!(t <= 25, "never more threads than restarts/4");
    }

    #[test]
    fn a3_more_restarts_no_worse() {
        let bow = tiny();
        let few = partition(&bow, 6, Algorithm::A3 { restarts: 1 }, 5);
        let many = partition(&bow, 6, Algorithm::A3 { restarts: 16 }, 5);
        assert!(many.eta >= few.eta - 1e-12);
    }

    #[test]
    fn degenerate_p_exceeds_items_yields_valid_plans() {
        // Regression for the `p > items` regime: more groups than
        // documents AND than words must produce valid, non-panicking
        // plans with η in (0, 1] for every algorithm — empty groups are
        // legal and must flow through the cost matrix, η, the partition
        // map, and a real training sweep.
        use crate::corpus::bow::BagOfWords;
        use crate::scheduler::exec::{ExecMode, ParallelLda};

        let bow =
            BagOfWords::from_triplets(3, 2, [(0, 0, 5), (1, 1, 2), (2, 0, 1), (1, 0, 4)]);
        for algo in [
            Algorithm::Baseline { restarts: 2 },
            Algorithm::A1,
            Algorithm::A2,
            Algorithm::A3 { restarts: 2 },
        ] {
            let plan = partition(&bow, 8, algo, 13);
            assert_eq!(plan.p, 8);
            assert_eq!(plan.doc_group.len(), 3);
            assert_eq!(plan.word_group.len(), 2);
            assert!(plan.doc_group.iter().all(|&g| (g as usize) < 8));
            assert!(plan.word_group.iter().all(|&g| (g as usize) < 8));
            assert!(
                plan.eta > 0.0 && plan.eta <= 1.0 + 1e-12,
                "{}: eta={}",
                algo.name(),
                plan.eta
            );
            assert_eq!(plan.costs.total(), bow.num_tokens());
            // The plan must also execute: one sweep over the mostly-empty
            // grid keeps every invariant.
            let mut lda = ParallelLda::init(&bow, &plan, 4, 0.5, 0.1, 13);
            let stats = lda.sweep(ExecMode::Sequential);
            assert_eq!(stats.total_tokens, bow.num_tokens());
            assert_eq!(lda.counts.total(), bow.num_tokens());
            assert!(lda.counts.check_consistency(&lda.all_blocks()).is_ok());
        }
    }

    #[test]
    fn proposed_beat_baseline_on_realistic_corpus() {
        // The paper's headline claim, checked in-miniature: on a skewed
        // corpus with P in the load-sensitive regime, A3 beats the
        // baseline at equal restarts.
        let bow = generate(&Profile::nips_like().scaled(20), 11);
        let p = 16;
        let base = partition(&bow, p, Algorithm::Baseline { restarts: 10 }, 3);
        let a3 = partition(&bow, p, Algorithm::A3 { restarts: 10 }, 3);
        assert!(
            a3.eta > base.eta,
            "A3 {} should beat baseline {}",
            a3.eta,
            base.eta
        );
    }
}
