//! Cost function and load-balancing ratio (paper Eq. 1–2).
//!
//! Given group assignments, the `P×P` cost matrix is accumulated in a
//! single pass over the nonzero cells of the workload matrix:
//! `C_mn = Σ_{j∈J_m, w∈V_n} r_jw`. Diagonal `l` holds partitions
//! `(m, (m+l) mod P)`; its epoch cost is the max over `m`, and
//! `C = Σ_l max_m C_{m,(m+l) mod P}`, `η = C_opt / C`, `C_opt = N/P`.

use crate::corpus::bow::BagOfWords;
use crate::partition::Plan;
use crate::scheduler::schedule::Schedule;

/// Dense `P×P` token-cost matrix, row-major.
#[derive(Clone, Debug)]
pub struct CostMatrix {
    p: usize,
    costs: Vec<u64>,
}

impl CostMatrix {
    /// Accumulate partition costs from the corpus in one nnz pass.
    pub fn compute(bow: &BagOfWords, doc_group: &[u32], word_group: &[u32]) -> Self {
        let p = doc_group
            .iter()
            .chain(word_group.iter())
            .max()
            .map(|&g| g as usize + 1)
            .unwrap_or(1);
        Self::compute_p(bow, doc_group, word_group, p)
    }

    /// Same, with an explicit `P` (groups may be empty).
    pub fn compute_p(
        bow: &BagOfWords,
        doc_group: &[u32],
        word_group: &[u32],
        p: usize,
    ) -> Self {
        assert_eq!(doc_group.len(), bow.num_docs());
        assert_eq!(word_group.len(), bow.num_words());
        let mut costs = vec![0u64; p * p];
        for j in 0..bow.num_docs() {
            let m = doc_group[j] as usize;
            let row = &mut costs[m * p..(m + 1) * p];
            for e in bow.doc(j) {
                row[word_group[e.word as usize] as usize] += e.count as u64;
            }
        }
        Self { p, costs }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn get(&self, m: usize, n: usize) -> u64 {
        self.costs[m * self.p + n]
    }

    pub fn total(&self) -> u64 {
        self.costs.iter().sum()
    }

    /// Epoch cost of diagonal `l`: `max_m C_{m,(m+l) mod P}`.
    pub fn diagonal_max(&self, l: usize) -> u64 {
        (0..self.p)
            .map(|m| self.get(m, (m + l) % self.p))
            .max()
            .unwrap_or(0)
    }

    /// Tokens on diagonal `l`.
    pub fn diagonal_sum(&self, l: usize) -> u64 {
        (0..self.p).map(|m| self.get(m, (m + l) % self.p)).sum()
    }

    /// Eq. 1: `C = Σ_l max_m C_{m,(m+l) mod P}`.
    pub fn sweep_cost(&self) -> u64 {
        (0..self.p).map(|l| self.diagonal_max(l)).sum()
    }
}

/// η and its ingredients, for reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EtaReport {
    pub eta: f64,
    /// Eq. 1 sweep cost in tokens.
    pub cost: f64,
    /// `C_opt = N / P`.
    pub opt: f64,
}

/// Eq. 2: `η = C_opt / C` for a group assignment.
pub fn eta(bow: &BagOfWords, doc_group: &[u32], word_group: &[u32], p: usize) -> EtaReport {
    let costs = CostMatrix::compute_p(bow, doc_group, word_group, p);
    eta_of_costs(&costs, bow.num_tokens())
}

/// η from a precomputed cost matrix.
pub fn eta_of_costs(costs: &CostMatrix, num_tokens: u64) -> EtaReport {
    let c = costs.sweep_cost() as f64;
    let opt = num_tokens as f64 / costs.p() as f64;
    let eta = if c > 0.0 { opt / c } else { 1.0 };
    EtaReport { eta, cost: c, opt }
}

/// The theoretical speedup of the partitioned parallel algorithm
/// (paper §VI-C): `speedup ≈ η · P`.
pub fn speedup(eta: f64, p: usize) -> f64 {
    eta * p as f64
}

/// Schedule-aware cost and ratio: `C_sched = Σ_l max_w assigned(w, l)`
/// (the per-epoch critical path over the schedule's `W` workers) with
/// `C_opt = N / W`. Under the diagonal schedule this reduces exactly to
/// Eq. 1–2; under packing it measures what the executor actually waits
/// on, which the plan-level η cannot see.
pub fn eta_of_schedule(costs: &CostMatrix, schedule: &Schedule, num_tokens: u64) -> EtaReport {
    assert_eq!(costs.p(), schedule.grid, "schedule built for another grid");
    let c = schedule.cost(costs) as f64;
    let opt = num_tokens as f64 / schedule.workers as f64;
    let eta = if c > 0.0 { opt / c } else { 1.0 };
    EtaReport { eta, cost: c, opt }
}

/// Plan-η (grid `P`, diagonal epochs on `P` workers) against schedule-η
/// (the same grid executed on the schedule's `W` workers). The paper
/// only ever reports the former; the latter is what a `W`-core box
/// actually achieves once the grid is over-decomposed.
#[derive(Clone, Copy, Debug)]
pub struct EtaComparison {
    /// Grid size `P` of the plan.
    pub grid: usize,
    /// Worker count `W` of the schedule.
    pub workers: usize,
    /// Eq. 1–2 η of the plan at `P` workers.
    pub plan: EtaReport,
    /// Schedule-aware η at `W` workers.
    pub schedule: EtaReport,
}

impl EtaComparison {
    pub fn of(plan: &Plan, schedule: &Schedule) -> Self {
        assert_eq!(plan.p, schedule.grid, "schedule built for another plan");
        let n = plan.costs.total();
        Self {
            grid: plan.p,
            workers: schedule.workers,
            plan: eta_of_costs(&plan.costs, n),
            schedule: eta_of_schedule(&plan.costs, schedule, n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::bow::BagOfWords;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    /// 2-doc, 2-word corpus with a perfectly balanced 2×2 split.
    #[test]
    fn perfect_balance_eta_one() {
        // r = [[2, 1], [1, 2]]; groups: doc i → i, word i → i.
        let bow = BagOfWords::from_triplets(
            2,
            2,
            [(0, 0, 2), (0, 1, 1), (1, 0, 1), (1, 1, 2)],
        );
        let r = eta(&bow, &[0, 1], &[0, 1], 2);
        // Diagonals: l=0 → {C00=2, C11=2} max 2; l=1 → {C01=1, C10=1} max 1.
        // C = 3, opt = 6/2 = 3 → η = 1.
        assert!((r.eta - 1.0).abs() < 1e-12);
        assert_eq!(r.cost, 3.0);
    }

    #[test]
    fn imbalance_lowers_eta() {
        // All mass in one partition.
        let bow = BagOfWords::from_triplets(2, 2, [(0, 0, 8), (1, 1, 1)]);
        let r = eta(&bow, &[0, 1], &[0, 1], 2);
        // C00=8, C11=1 → diag0 max 8; diag1 max 0. C=8, opt=4.5, η=0.5625.
        assert!((r.eta - 4.5 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn cost_matrix_entries() {
        let bow = BagOfWords::from_triplets(
            3,
            3,
            [(0, 0, 1), (0, 2, 2), (1, 1, 3), (2, 0, 4), (2, 2, 5)],
        );
        let cm = CostMatrix::compute_p(&bow, &[0, 1, 2], &[0, 1, 2], 3);
        assert_eq!(cm.get(0, 0), 1);
        assert_eq!(cm.get(0, 2), 2);
        assert_eq!(cm.get(1, 1), 3);
        assert_eq!(cm.get(2, 0), 4);
        assert_eq!(cm.get(2, 2), 5);
        assert_eq!(cm.total(), 15);
        assert_eq!(cm.total(), bow.num_tokens());
    }

    #[test]
    fn diagonal_partition_cover_is_exact() {
        // Every partition belongs to exactly one diagonal ⇒ Σ_l diag_sum(l)
        // = total tokens.
        prop::check("diagonal-cover", 0xD1A6, 32, |rng| {
            let d = prop::gen_size(rng, 1, 40);
            let w = prop::gen_size(rng, 1, 40);
            let p = 1 + rng.gen_range(8);
            let bow = random_bow(rng, d, w);
            let (dg, wg) = random_groups(rng, d, w, p);
            let cm = CostMatrix::compute_p(&bow, &dg, &wg, p);
            let diag_total: u64 = (0..p).map(|l| cm.diagonal_sum(l)).sum();
            assert_eq!(diag_total, bow.num_tokens());
            assert_eq!(cm.total(), bow.num_tokens());
        });
    }

    #[test]
    fn eta_bounds_property() {
        prop::check("eta-bounds", 0xE7A, 32, |rng| {
            let d = prop::gen_size(rng, 1, 60);
            let w = prop::gen_size(rng, 1, 60);
            let p = 1 + rng.gen_range(8);
            let bow = random_bow(rng, d, w);
            if bow.num_tokens() == 0 {
                return;
            }
            let (dg, wg) = random_groups(rng, d, w, p);
            let r = eta(&bow, &dg, &wg, p);
            assert!(r.eta > 0.0 && r.eta <= 1.0 + 1e-12, "eta {}", r.eta);
            assert!(r.cost >= r.opt - 1e-9, "C {} < C_opt {}", r.cost, r.opt);
        });
    }

    fn random_bow(rng: &mut Rng, d: usize, w: usize) -> BagOfWords {
        let nnz = prop::gen_size(rng, 1, d * w.min(20));
        let triplets: Vec<(u32, u32, u32)> = (0..nnz)
            .map(|_| {
                (
                    rng.gen_range(d) as u32,
                    rng.gen_range(w) as u32,
                    1 + rng.gen_range(9) as u32,
                )
            })
            .collect();
        BagOfWords::from_triplets(d, w, triplets)
    }

    fn random_groups(
        rng: &mut Rng,
        d: usize,
        w: usize,
        p: usize,
    ) -> (Vec<u32>, Vec<u32>) {
        (
            (0..d).map(|_| rng.gen_range(p) as u32).collect(),
            (0..w).map(|_| rng.gen_range(p) as u32).collect(),
        )
    }

    #[test]
    fn speedup_model() {
        assert_eq!(speedup(0.5, 10), 5.0);
        assert_eq!(speedup(1.0, 30), 30.0);
    }

    #[test]
    fn schedule_eta_reduces_to_plan_eta_under_diagonal() {
        use crate::corpus::synthetic::{generate, Profile};
        use crate::partition::{partition, Algorithm};
        use crate::scheduler::schedule::{Schedule, ScheduleKind};

        let bow = generate(&Profile::tiny(), 9);
        let plan = partition(&bow, 4, Algorithm::A3 { restarts: 2 }, 9);
        let s = Schedule::build(ScheduleKind::Diagonal, &plan.costs, 4);
        let cmp = EtaComparison::of(&plan, &s);
        assert_eq!(cmp.grid, 4);
        assert_eq!(cmp.workers, 4);
        assert!((cmp.plan.eta - plan.eta).abs() < 1e-12);
        assert!((cmp.schedule.eta - cmp.plan.eta).abs() < 1e-12);
        assert_eq!(cmp.schedule.cost, cmp.plan.cost);
    }

    #[test]
    fn packed_schedule_eta_bounds() {
        use crate::corpus::synthetic::{generate, Profile};
        use crate::partition::{partition, Algorithm};
        use crate::scheduler::schedule::{Schedule, ScheduleKind};

        let bow = generate(&Profile::tiny(), 10);
        let w = 2;
        for g in [1usize, 2, 4] {
            let plan = partition(&bow, g * w, Algorithm::A3 { restarts: 2 }, 10);
            let s = Schedule::build(ScheduleKind::Packed { grid_factor: g }, &plan.costs, w);
            let r = eta_of_schedule(&plan.costs, &s, bow.num_tokens());
            // The critical path can never beat N/W, so η ≤ 1; it is also
            // positive on a non-empty corpus.
            assert!(r.eta > 0.0 && r.eta <= 1.0 + 1e-12, "g={g}: eta {}", r.eta);
            assert!(r.cost >= r.opt - 1e-9, "g={g}: C {} < C_opt {}", r.cost, r.opt);
        }
    }
}
