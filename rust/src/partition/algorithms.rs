//! The four partitioning algorithms (paper §IV-B), assembled from
//! [`super::permutation`] orderings + [`super::split`] equal-mass cuts +
//! [`super::eta`] scoring.

use crate::corpus::bow::BagOfWords;
use crate::partition::{eta, permutation, split, Plan};
use crate::util::rng::Rng;

fn make_plan(
    bow: &BagOfWords,
    p: usize,
    doc_order: &[u32],
    word_order: &[u32],
    algorithm: &'static str,
) -> Plan {
    let doc_group = split::split_equal_mass(doc_order, bow.row_sums(), p);
    let word_group = split::split_equal_mass(word_order, bow.col_sums(), p);
    let costs = eta::CostMatrix::compute_p(bow, &doc_group, &word_group, p);
    let report = eta::eta_of_costs(&costs, bow.num_tokens());
    Plan {
        p,
        doc_group,
        word_group,
        eta: report.eta,
        cost: report.cost,
        costs,
        algorithm,
    }
}

/// Algorithm A1 (deterministic): Heuristic-1 interposition from the front.
pub fn run_a1(bow: &BagOfWords, p: usize) -> Plan {
    let doc_order = permutation::interpose_front(bow.row_sums());
    let word_order = permutation::interpose_front(bow.col_sums());
    make_plan(bow, p, &doc_order, &word_order, "A1")
}

/// Algorithm A2 (deterministic): Heuristic-2 interposition from both ends.
pub fn run_a2(bow: &BagOfWords, p: usize) -> Plan {
    let doc_order = permutation::interpose_both_ends(bow.row_sums());
    let word_order = permutation::interpose_both_ends(bow.col_sums());
    make_plan(bow, p, &doc_order, &word_order, "A2")
}

/// One randomized draw of Algorithm A3 (stratified shuffle). The caller
/// repeats and keeps the best η (paper: 100–200 repetitions).
pub fn run_a3_once(bow: &BagOfWords, p: usize, rng: &mut Rng) -> Plan {
    let doc_order = permutation::stratified_shuffle(bow.row_sums(), p, rng);
    let word_order = permutation::stratified_shuffle(bow.col_sums(), p, rng);
    make_plan(bow, p, &doc_order, &word_order, "A3")
}

/// Best-of-`restarts` independent plan draws, fanned out over up to
/// `threads` OS threads.
///
/// `run(t)` must be a pure function of the draw index `t` (both A3 and
/// the baseline key their RNG stream by `t`), so the draws are
/// embarrassingly parallel and the result cannot depend on the thread
/// count: every draw is evaluated identically, and the reduction keeps
/// the best η with ties broken toward the lowest `t` — exactly the plan
/// the serial loop keeps (it only replaces on *strictly* better η, i.e.
/// the earliest argmax wins). `threads == 1` is the serial reference
/// path, with no spawns at all.
///
/// The paper's A3/baseline budgets are 100–200 repetitions, each a full
/// permutation + equal-mass split + nnz cost pass — by far the dominant
/// partitioning cost (see `bench_partitioner_runtime`), and the reason
/// this fan-out exists.
pub fn best_plan_parallel(
    restarts: usize,
    threads: usize,
    run: impl Fn(usize) -> Plan + Sync,
) -> Plan {
    assert!(restarts >= 1, "need at least one draw");
    let threads = threads.clamp(1, restarts);
    // Serial-vs-parallel reduction helper: strictly better η wins; on
    // exactly equal η the lower draw index wins.
    let better = |cand: &(usize, Plan), best: &Option<(usize, Plan)>| -> bool {
        match best {
            None => true,
            Some((bt, b)) => cand.1.eta > b.eta || (cand.1.eta == b.eta && cand.0 < *bt),
        }
    };
    if threads == 1 {
        let mut best: Option<(usize, Plan)> = None;
        for t in 0..restarts {
            let cand = (t, run(t));
            if better(&cand, &best) {
                best = Some(cand);
            }
        }
        return best.unwrap().1;
    }
    let run = &run;
    let better = &better;
    let mut per_thread: Vec<Option<(usize, Plan)>> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|c| {
                s.spawn(move || {
                    // Strided draw assignment: thread `c` evaluates draws
                    // c, c+threads, c+2·threads, … — a pure partition of
                    // the index space, independent of timing. The
                    // per-thread reduction shares `better` with the
                    // cross-thread reduction below, so the two can never
                    // diverge.
                    let mut best: Option<(usize, Plan)> = None;
                    let mut t = c;
                    while t < restarts {
                        let cand = (t, run(t));
                        if better(&cand, &best) {
                            best = Some(cand);
                        }
                        t += threads;
                    }
                    best
                })
            })
            .collect();
        per_thread = handles
            .into_iter()
            .map(|h| h.join().expect("plan-draw thread panicked"))
            .collect();
    });
    let mut best: Option<(usize, Plan)> = None;
    for cand in per_thread.into_iter().flatten() {
        if better(&cand, &best) {
            best = Some(cand);
        }
    }
    best.unwrap().1
}

/// One randomized draw of the Yan et al. baseline: uniform shuffle, then
/// split into `P` groups of equal *cardinality* (equal numbers of
/// documents/words, the GPU-index-range split of the original algorithm —
/// this, not the shuffle, is what the proposed algorithms improve on).
/// The caller repeats and keeps the best η.
pub fn run_baseline_once(bow: &BagOfWords, p: usize, rng: &mut Rng) -> Plan {
    let doc_order = permutation::uniform_shuffle(bow.num_docs(), rng);
    let word_order = permutation::uniform_shuffle(bow.num_words(), rng);
    let doc_group = split::split_equal_count(&doc_order, p);
    let word_group = split::split_equal_count(&word_order, p);
    let costs = eta::CostMatrix::compute_p(bow, &doc_group, &word_group, p);
    let report = eta::eta_of_costs(&costs, bow.num_tokens());
    Plan {
        p,
        doc_group,
        word_group,
        eta: report.eta,
        cost: report.cost,
        costs,
        algorithm: "baseline",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::testing::prop;

    #[test]
    fn group_masses_are_balanced_for_a1() {
        let bow = generate(&Profile::tiny(), 3);
        let p = 5;
        let plan = run_a1(&bow, p);
        let masses =
            split::group_masses(&plan.doc_group, bow.row_sums(), p);
        let total: u64 = masses.iter().sum();
        let ideal = total as f64 / p as f64;
        for &m in &masses {
            assert!(
                (m as f64 - ideal).abs() < ideal * 0.5,
                "doc group mass {m} far from ideal {ideal}"
            );
        }
    }

    #[test]
    fn plans_expose_cost_matrix_consistent_with_eta() {
        let bow = generate(&Profile::tiny(), 4);
        let plan = run_a2(&bow, 4);
        let recomputed = eta::eta(&bow, &plan.doc_group, &plan.word_group, 4);
        assert!((plan.eta - recomputed.eta).abs() < 1e-12);
        assert_eq!(plan.costs.total(), bow.num_tokens());
    }

    #[test]
    fn a3_beats_first_draw_of_baseline_usually() {
        // Not a theorem for single draws, but over a heavy corpus and
        // several seeds A3's stratified draw should dominate the uniform
        // draw on average.
        let bow = generate(&Profile::nips_like().scaled(40), 6);
        let p = 12;
        let mut a3_wins = 0;
        let trials = 10;
        for s in 0..trials {
            let mut r1 = Rng::stream(100 + s, 0);
            let mut r2 = Rng::stream(200 + s, 0);
            let a3 = run_a3_once(&bow, p, &mut r1);
            let base = run_baseline_once(&bow, p, &mut r2);
            if a3.eta > base.eta {
                a3_wins += 1;
            }
        }
        assert!(a3_wins >= 7, "A3 won only {a3_wins}/{trials} single draws");
    }

    #[test]
    fn all_algorithms_valid_on_degenerate_inputs() {
        prop::check("algorithms-degenerate", 0xDE6, 24, |rng| {
            let d = prop::gen_size(rng, 1, 30);
            let w = prop::gen_size(rng, 1, 30);
            let p = 1 + rng.gen_range(10);
            let triplets: Vec<(u32, u32, u32)> = (0..prop::gen_size(rng, 0, 60))
                .map(|_| {
                    (
                        rng.gen_range(d) as u32,
                        rng.gen_range(w) as u32,
                        1 + rng.gen_range(5) as u32,
                    )
                })
                .collect();
            let bow = BagOfWords::from_triplets(d, w, triplets);
            for plan in [
                run_a1(&bow, p),
                run_a2(&bow, p),
                run_a3_once(&bow, p, rng),
                run_baseline_once(&bow, p, rng),
            ] {
                assert_eq!(plan.doc_group.len(), d);
                assert_eq!(plan.word_group.len(), w);
                assert!(plan.doc_group.iter().all(|&g| (g as usize) < p));
                assert!(plan.word_group.iter().all(|&g| (g as usize) < p));
                if bow.num_tokens() > 0 {
                    assert!(plan.eta > 0.0 && plan.eta <= 1.0 + 1e-12);
                }
            }
        });
    }
}
