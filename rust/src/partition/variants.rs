//! Symmetric variants of the deterministic algorithms.
//!
//! Paper §IV-A, closing note: "the considered matrix is not symmetric, so
//! other similar permutations can be achieved by swapping the resulting
//! matrix symmetrically vertically and/or horizontally after applying
//! these heuristics." Reversing a row/column *ordering* before the
//! equal-mass split realizes exactly those swaps, and the split boundaries
//! land differently on each mirror, so the four variants
//! {identity, flip-rows} × {identity, flip-cols} generally produce four
//! distinct η. This module tries all four and keeps the best — still
//! deterministic, still ~two orders of magnitude faster than the
//! randomized algorithms (4 split+score passes instead of 1, vs ≥100).

use crate::corpus::bow::BagOfWords;
use crate::partition::{eta, permutation, split, Plan};

/// Which deterministic heuristic to mirror.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Base {
    A1,
    A2,
}

/// Run the 4 symmetric variants of `base` and return the best plan.
pub fn run_symmetric(bow: &BagOfWords, p: usize, base: Base) -> Plan {
    let (doc_order, word_order, name) = match base {
        Base::A1 => (
            permutation::interpose_front(bow.row_sums()),
            permutation::interpose_front(bow.col_sums()),
            "A1sym",
        ),
        Base::A2 => (
            permutation::interpose_both_ends(bow.row_sums()),
            permutation::interpose_both_ends(bow.col_sums()),
            "A2sym",
        ),
    };

    let mut best: Option<Plan> = None;
    for flip_rows in [false, true] {
        for flip_cols in [false, true] {
            let dorder = maybe_flip(&doc_order, flip_rows);
            let worder = maybe_flip(&word_order, flip_cols);
            let doc_group = split::split_equal_mass(&dorder, bow.row_sums(), p);
            let word_group = split::split_equal_mass(&worder, bow.col_sums(), p);
            let costs = eta::CostMatrix::compute_p(bow, &doc_group, &word_group, p);
            let report = eta::eta_of_costs(&costs, bow.num_tokens());
            let plan = Plan {
                p,
                doc_group,
                word_group,
                eta: report.eta,
                cost: report.cost,
                costs,
                algorithm: name,
            };
            if best.as_ref().map(|b| plan.eta > b.eta).unwrap_or(true) {
                best = Some(plan);
            }
        }
    }
    best.unwrap()
}

fn maybe_flip(order: &[u32], flip: bool) -> Vec<u32> {
    if flip {
        order.iter().rev().copied().collect()
    } else {
        order.to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};

    #[test]
    fn symmetric_never_worse_than_base() {
        let bow = generate(&Profile::nips_like().scaled(10), 7);
        for p in [8usize, 16, 30] {
            let a1 = partition(&bow, p, Algorithm::A1, 0);
            let a1s = run_symmetric(&bow, p, Base::A1);
            assert!(
                a1s.eta >= a1.eta - 1e-12,
                "P={p}: A1sym {} < A1 {}",
                a1s.eta,
                a1.eta
            );
            let a2 = partition(&bow, p, Algorithm::A2, 0);
            let a2s = run_symmetric(&bow, p, Base::A2);
            assert!(a2s.eta >= a2.eta - 1e-12);
        }
    }

    #[test]
    fn symmetric_is_deterministic() {
        let bow = generate(&Profile::tiny(), 8);
        let a = run_symmetric(&bow, 5, Base::A1);
        let b = run_symmetric(&bow, 5, Base::A1);
        assert_eq!(a.doc_group, b.doc_group);
        assert_eq!(a.word_group, b.word_group);
    }

    #[test]
    fn symmetric_plans_are_valid() {
        let bow = generate(&Profile::tiny(), 9);
        for base in [Base::A1, Base::A2] {
            let plan = run_symmetric(&bow, 4, base);
            assert_eq!(plan.doc_group.len(), bow.num_docs());
            assert_eq!(plan.word_group.len(), bow.num_words());
            assert!(plan.eta > 0.0 && plan.eta <= 1.0 + 1e-12);
            assert_eq!(plan.costs.total(), bow.num_tokens());
        }
    }
}
