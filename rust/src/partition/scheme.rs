//! The `P×P` partition map (paper Fig. 1): materialized per-partition cell
//! lists plus the diagonal structure the scheduler executes.
//!
//! Diagonal `l` consists of the partitions `(m, (m+l) mod P)` for
//! `m = 0..P`. Within a diagonal the row groups `{J_m}` are pairwise
//! disjoint and the column groups `{V_{(m+l) mod P}}` are pairwise
//! disjoint, so the `P` partitions touch disjoint rows of the
//! document–topic counts and disjoint columns of the topic–word counts —
//! the read–write non-conflict property that lets them be sampled in
//! parallel on shared state (only the topic totals `n_k` race, which the
//! engine handles with per-worker deltas merged at the epoch barrier).

use crate::corpus::bow::BagOfWords;
use crate::partition::Plan;

/// One nonzero cell of a partition: document, word, count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cell {
    pub doc: u32,
    pub word: u32,
    pub count: u32,
}

/// Materialized partitions of one corpus under one plan.
#[derive(Clone, Debug)]
pub struct PartitionMap {
    p: usize,
    /// Cells per partition, row-major `[m * p + n]`. Within a partition,
    /// cells are grouped by document (ascending) then word (ascending).
    cells: Vec<Vec<Cell>>,
    /// Token count per partition (must equal `Plan.costs`).
    tokens: Vec<u64>,
}

impl PartitionMap {
    /// Distribute every nonzero cell of `bow` into its partition.
    pub fn build(bow: &BagOfWords, plan: &Plan) -> Self {
        let p = plan.p;
        assert_eq!(plan.doc_group.len(), bow.num_docs());
        assert_eq!(plan.word_group.len(), bow.num_words());
        let mut cells: Vec<Vec<Cell>> = vec![Vec::new(); p * p];
        let mut tokens = vec![0u64; p * p];
        for j in 0..bow.num_docs() {
            let m = plan.doc_group[j] as usize;
            for e in bow.doc(j) {
                let n = plan.word_group[e.word as usize] as usize;
                cells[m * p + n].push(Cell {
                    doc: j as u32,
                    word: e.word,
                    count: e.count,
                });
                tokens[m * p + n] += e.count as u64;
            }
        }
        Self { p, cells, tokens }
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn cells(&self, m: usize, n: usize) -> &[Cell] {
        &self.cells[m * self.p + n]
    }

    #[inline]
    pub fn tokens(&self, m: usize, n: usize) -> u64 {
        self.tokens[m * self.p + n]
    }

    /// The partitions of diagonal `l`, as `(m, n)` pairs — the unit of
    /// parallel execution.
    pub fn diagonal(&self, l: usize) -> impl Iterator<Item = (usize, usize)> + '_ {
        let p = self.p;
        (0..p).map(move |m| (m, (m + l) % p))
    }

    pub fn total_tokens(&self) -> u64 {
        self.tokens.iter().sum()
    }

    /// Memory footprint of the materialized cells, in bytes.
    pub fn cell_bytes(&self) -> usize {
        self.cells.iter().map(|c| c.len() * std::mem::size_of::<Cell>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};
    use crate::partition::{partition, Algorithm};
    use crate::testing::prop;

    fn build_tiny(p: usize, seed: u64) -> (BagOfWords, Plan, PartitionMap) {
        let bow = generate(&Profile::tiny(), seed);
        let plan = partition(&bow, p, Algorithm::A3 { restarts: 2 }, seed);
        let map = PartitionMap::build(&bow, &plan);
        (bow, plan, map)
    }

    #[test]
    fn cells_cover_all_tokens() {
        let (bow, plan, map) = build_tiny(4, 1);
        assert_eq!(map.total_tokens(), bow.num_tokens());
        // Per-partition counts agree with the plan's cost matrix.
        for m in 0..4 {
            for n in 0..4 {
                assert_eq!(map.tokens(m, n), plan.costs.get(m, n));
            }
        }
    }

    #[test]
    fn cells_respect_their_groups() {
        let (_bow, plan, map) = build_tiny(3, 2);
        for m in 0..3 {
            for n in 0..3 {
                for c in map.cells(m, n) {
                    assert_eq!(plan.doc_group[c.doc as usize] as usize, m);
                    assert_eq!(plan.word_group[c.word as usize] as usize, n);
                }
            }
        }
    }

    #[test]
    fn diagonals_enumerate_all_partitions_once() {
        let (_bow, _plan, map) = build_tiny(5, 3);
        let mut seen = vec![false; 25];
        for l in 0..5 {
            for (m, n) in map.diagonal(l) {
                assert!(!seen[m * 5 + n], "partition visited twice");
                seen[m * 5 + n] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn diagonal_nonconflict_property() {
        // Fig. 1's invariant: within any diagonal, no two partitions share
        // a row group or a column group.
        prop::check("diagonal-nonconflict", 0xF161, 32, |rng| {
            let p = 1 + rng.gen_range(12);
            for l in 0..p {
                let mut rows_seen = vec![false; p];
                let mut cols_seen = vec![false; p];
                for m in 0..p {
                    let n = (m + l) % p;
                    assert!(!rows_seen[m] && !cols_seen[n], "conflict in diagonal");
                    rows_seen[m] = true;
                    cols_seen[n] = true;
                }
            }
        });
    }

    #[test]
    fn cell_bytes_reports_footprint() {
        let (_bow, _plan, map) = build_tiny(2, 4);
        assert!(map.cell_bytes() > 0);
        assert_eq!(map.cell_bytes() % std::mem::size_of::<Cell>(), 0);
    }
}
