//! Cut an ordered item list into `P` consecutive groups of approximately
//! equal weight (paper: "Divide RR into P consecutive groups J_1..J_P,
//! each one having an equal number of word tokens").
//!
//! Each item is assigned by the *midpoint rule*: an item whose prefix-mass
//! midpoint `cum + w/2` falls inside `[g·total/P, (g+1)·total/P)` joins
//! group `g`. Midpoints are strictly increasing along the order, so groups
//! are consecutive by construction; a group's mass exceeds the ideal
//! `total/P` by at most one item's weight — exact in the regime where item
//! weights are small relative to `total/P` (document/word workloads), and
//! graceful in the degenerate regimes (P > n, giant single items, empty
//! groups when unavoidable).

/// Assign group ids (`0..p`) to items *in the given order*; returns a
/// vector parallel to `order` mapping item id → group.
pub fn split_equal_mass(order: &[u32], weights: &[u64], p: usize) -> Vec<u32> {
    assert!(p >= 1);
    let n = order.len();
    let mut group_of = vec![0u32; weights.len()];
    if n == 0 {
        return group_of;
    }
    let total: u64 = order.iter().map(|&i| weights[i as usize]).sum();
    if p == 1 {
        return group_of;
    }
    if total == 0 || n <= p {
        // Degenerate regimes: a zero-mass list, or at least as many
        // groups as items. Spread items in order — for `n ≤ p` every
        // item lands in its own group (`pos·p/n` advances by ≥ 1 per
        // position), which dominates the midpoint rule there: midpoints
        // of several light items can collapse into one group while most
        // groups sit empty, needlessly capping η at the diagonal max of
        // a stacked group. Trailing empty groups are valid plans — the
        // cost matrix, η, and the executor all tolerate empty
        // partitions.
        for (pos, &i) in order.iter().enumerate() {
            group_of[i as usize] = ((pos * p) / n) as u32;
        }
        return group_of;
    }

    let mut cum = 0u64; // mass emitted before the current item
    for &item in order {
        let w = weights[item as usize];
        // Midpoint rule: 2*mid*p / (2*total), computed in u128 to avoid
        // overflow on corpus-scale token counts.
        let mid2 = 2 * cum as u128 + w as u128; // 2 × midpoint
        let g = (mid2 * p as u128 / (2 * total as u128)).min(p as u128 - 1);
        group_of[item as usize] = g as u32;
        cum += w;
    }
    group_of
}

/// Split into `P` consecutive groups of equal *cardinality* (ignoring
/// weights) — the split used by the Yan et al. baseline, which balances
/// index ranges rather than token mass.
pub fn split_equal_count(order: &[u32], p: usize) -> Vec<u32> {
    assert!(p >= 1);
    let n = order.len();
    let mut group_of = vec![0u32; n];
    for (pos, &item) in order.iter().enumerate() {
        group_of[item as usize] = ((pos * p) / n.max(1)) as u32;
    }
    group_of
}

/// Total weight per group (diagnostic).
pub fn group_masses(group_of: &[u32], weights: &[u64], p: usize) -> Vec<u64> {
    let mut masses = vec![0u64; p];
    for (i, &g) in group_of.iter().enumerate() {
        masses[g as usize] += weights[i];
    }
    masses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn uniform_items_split_evenly() {
        let order: Vec<u32> = (0..12).collect();
        let w = vec![1u64; 12];
        let g = split_equal_mass(&order, &w, 4);
        let masses = group_masses(&g, &w, 4);
        assert_eq!(masses, vec![3, 3, 3, 3]);
        // Groups are consecutive in order.
        assert_eq!(g, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn skewed_items_balance_mass_not_count() {
        let order: Vec<u32> = (0..4).collect();
        let w = vec![9u64, 1, 1, 1];
        let g = split_equal_mass(&order, &w, 2);
        let masses = group_masses(&g, &w, 2);
        // Best cut: [9] vs [1,1,1].
        assert_eq!(masses, vec![9, 3]);
    }

    #[test]
    fn p1_everything_one_group() {
        let order: Vec<u32> = (0..5).collect();
        let g = split_equal_mass(&order, &[5, 4, 3, 2, 1], 1);
        assert!(g.iter().all(|&x| x == 0));
    }

    #[test]
    fn zero_mass_round_robins() {
        let order: Vec<u32> = (0..6).collect();
        let g = split_equal_mass(&order, &[0; 6], 3);
        let mut counts = [0; 3];
        for &x in &g {
            counts[x as usize] += 1;
        }
        assert_eq!(counts, [2, 2, 2]);
    }

    #[test]
    fn fewer_items_than_groups() {
        let order: Vec<u32> = (0..2).collect();
        let g = split_equal_mass(&order, &[5, 5], 4);
        // Each item its own group; trailing groups empty is fine.
        assert!(g[0] != g[1]);
    }

    #[test]
    fn degenerate_p_ge_items_gives_every_item_its_own_group() {
        // The midpoint rule would stack the light items of [10, 1, 1]
        // into one group at P=8; the degenerate path must not.
        let order: Vec<u32> = (0..3).collect();
        let g = split_equal_mass(&order, &[10, 1, 1], 8);
        assert_eq!(g.len(), 3);
        let mut seen = std::collections::BTreeSet::new();
        for &x in &g {
            assert!((x as usize) < 8);
            assert!(seen.insert(x), "items stacked into group {x}");
        }
        // Same guarantee at the exact boundary n == p.
        let order: Vec<u32> = (0..4).collect();
        let g = split_equal_mass(&order, &[7, 5, 3, 1], 4);
        let mut sorted = g.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }

    #[test]
    fn degenerate_empty_order_is_valid() {
        let g = split_equal_mass(&[], &[], 5);
        assert!(g.is_empty());
        let g = split_equal_count(&[], 5);
        assert!(g.is_empty());
    }

    #[test]
    fn groups_monotone_along_order_property() {
        prop::check("split-monotone", 0x5911, 64, |rng| {
            let n = prop::gen_size(rng, 1, 300);
            let w: Vec<u64> = prop::gen_heavy_tailed(rng, n, 5_000)
                .into_iter()
                .map(u64::from)
                .collect();
            let p = 1 + rng.gen_range(12);
            let order: Vec<u32> = {
                let mut o: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut o);
                o
            };
            let g = split_equal_mass(&order, &w, p);
            // Monotone non-decreasing group ids along the order, all < p.
            let mut prev = 0u32;
            for &item in &order {
                let gi = g[item as usize];
                assert!(gi >= prev && (gi as usize) < p, "non-monotone groups");
                prev = gi;
            }
            // Balance: every group's mass ≤ ideal + max item weight.
            let total: u64 = w.iter().sum();
            let masses = group_masses(&g, &w, p);
            let wmax = *w.iter().max().unwrap() as f64;
            let ideal = total as f64 / p as f64;
            for &m in &masses {
                assert!(
                    (m as f64) <= ideal + wmax + 1e-9,
                    "group mass {m} > ideal {ideal} + wmax {wmax}"
                );
            }
        });
    }
}
