//! Training-set perplexity (paper Eq. 3–4):
//!
//! ```text
//! Perp(x)  = exp(−log p(x) / N)
//! log p(x) = Σ_{ji} log Σ_k θ_{k|j} φ_{x_ji|k}
//! θ_{k|j}  = (n_jk + α) / (n_j + Kα)
//! φ_{w|k}  = (n_kw + β) / (n_k + Wβ)
//! ```
//!
//! Computed per distinct cell (weighting by count) so the cost is
//! `O(nnz · K)` rather than `O(N · K)`. The same computation is available
//! through the AOT-compiled JAX/Pallas kernel via
//! [`crate::runtime::executor`]; this is the native reference.

use crate::corpus::bow::BagOfWords;
use crate::gibbs::counts::LdaCounts;
use crate::gibbs::sampler::Hyper;

/// log p(x) over the corpus under the current counts.
pub fn log_likelihood(bow: &BagOfWords, counts: &LdaCounts, h: &Hyper) -> f64 {
    let k = h.k;
    let kalpha = h.alpha as f64 * k as f64;

    // Precompute φ normalizers 1/(n_k + Wβ).
    let inv_nk: Vec<f64> = counts
        .topic
        .iter()
        .map(|&nk| 1.0 / (nk as f64 + h.wbeta as f64))
        .collect();

    let mut ll = 0.0f64;
    let mut theta = vec![0.0f64; k];
    for j in 0..bow.num_docs() {
        let row = counts.doc_row(j);
        let nj: u64 = row.iter().map(|&c| c as u64).sum();
        let inv_nj = 1.0 / (nj as f64 + kalpha);
        for t in 0..k {
            theta[t] = (row[t] as f64 + h.alpha as f64) * inv_nj;
        }
        for e in bow.doc(j) {
            let wrow = counts.word_row(e.word as usize);
            let mut p = 0.0f64;
            for t in 0..k {
                p += theta[t] * (wrow[t] as f64 + h.beta as f64) * inv_nk[t];
            }
            ll += e.count as f64 * p.ln();
        }
    }
    ll
}

/// Eq. 3: `exp(−log p / N)`.
pub fn perplexity(bow: &BagOfWords, counts: &LdaCounts, h: &Hyper) -> f64 {
    let n = bow.num_tokens();
    assert!(n > 0, "perplexity of empty corpus");
    (-log_likelihood(bow, counts, h) / n as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gibbs::tokens::TokenBlock;
    use crate::util::rng::Rng;

    fn setup(k: usize) -> (BagOfWords, LdaCounts, Hyper) {
        let bow = BagOfWords::from_triplets(
            3,
            6,
            [(0, 0, 3), (0, 1, 2), (1, 2, 4), (2, 3, 1), (2, 5, 2)],
        );
        let mut rng = Rng::new(5);
        let block = TokenBlock::from_corpus(&bow, k, &mut rng);
        let mut counts = LdaCounts::zeros(3, 6, k);
        counts.absorb(&block);
        (bow, counts, Hyper::new(k, 0.5, 0.1, 6))
    }

    #[test]
    fn perplexity_bounded_by_vocab() {
        let (bow, counts, h) = setup(4);
        let p = perplexity(&bow, &counts, &h);
        // Perplexity of any model is at most ~uniform over W (plus
        // smoothing slack) and at least 1.
        assert!(p >= 1.0, "{p}");
        assert!(p < 6.0 * 2.0, "{p}");
    }

    #[test]
    fn log_likelihood_is_negative() {
        let (bow, counts, h) = setup(4);
        assert!(log_likelihood(&bow, &counts, &h) < 0.0);
    }

    #[test]
    fn concentrated_counts_give_lower_perplexity() {
        // A model whose counts align doc 0 entirely with topic 0 over its
        // actual words must beat random counts.
        let (bow, random_counts, h) = setup(2);
        let mut aligned = LdaCounts::zeros(3, 6, 2);
        // Assign every token of doc j to topic j%2 deterministically.
        for j in 0..3 {
            for e in bow.doc(j) {
                let t = j % 2;
                aligned.doc_topic[j * 2 + t] += e.count as f32;
                aligned.word_topic[e.word as usize * 2 + t] += e.count as f32;
                aligned.topic[t] += e.count;
            }
        }
        let p_aligned = perplexity(&bow, &aligned, &h);
        let p_random = perplexity(&bow, &random_counts, &h);
        assert!(
            p_aligned < p_random,
            "aligned {p_aligned} should beat random {p_random}"
        );
    }
}
