//! The collapsed-Gibbs per-token sampling kernel — the hot path of the
//! whole system.
//!
//! For token `(j, w)` with current assignment `z`, the collapsed
//! conditional after removing the token is
//!
//! ```text
//! p(k) ∝ (n_jk + α) · (n_kw + β) / (n_k + Wβ)
//! ```
//!
//! Two variants exist:
//!
//! * [`sweep_serial`] — textbook collapsed Gibbs: `n_k` is updated
//!   immediately after every token. This is the nonparallel reference the
//!   paper compares against (Table IV "Nonparallel").
//! * [`sweep_partition`] — the parallel per-partition kernel: `n_jk` and
//!   `n_kw` rows are owned exclusively by the worker (diagonal
//!   non-conflict), while `n_k` is read from an epoch-start snapshot and
//!   the worker's increments/decrements accumulate in a local delta that
//!   the barrier merges (Yan et al.'s approximation).
//!
//! `sweep_partition` is the *dense* member of the pluggable kernel
//! subsystem: [`crate::kernel::DenseKernel`] wraps it behind the
//! [`crate::kernel::Kernel`] trait, next to the sparse-bucket and
//! alias-table kernels (see `docs/kernels.md`).

use crate::gibbs::tokens::TokenBlock;
use crate::util::rng::Rng;

/// LDA hyperparameters (paper §V-C: α=0.5, β=0.1, K=256).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub k: usize,
    pub alpha: f32,
    pub beta: f32,
    /// `W·β` — the φ normalizer constant.
    pub wbeta: f32,
}

impl Hyper {
    pub fn new(k: usize, alpha: f32, beta: f32, num_words: usize) -> Self {
        Self {
            k,
            alpha,
            beta,
            wbeta: beta * num_words as f32,
        }
    }
}

/// One serial sweep over a token block with immediate `n_k` updates.
/// `doc_topic`/`word_topic` are the full flat matrices.
pub fn sweep_serial(
    block: &mut TokenBlock,
    doc_topic: &mut [f32],
    word_topic: &mut [f32],
    topic: &mut [u32],
    h: &Hyper,
    rng: &mut Rng,
    probs: &mut Vec<f32>,
) {
    let k = h.k;
    probs.resize(k, 0.0);
    // Incrementally-maintained reciprocal of the φ normalizer:
    // inv[t] = 1/(n_k[t] + Wβ). Only two entries change per token, so
    // this turns K divisions per token into 2 — and the now
    // division-free inner loop auto-vectorizes (see EXPERIMENTS.md §Perf).
    let mut inv: Vec<f32> = topic
        .iter()
        .map(|&nk| 1.0 / (nk as f32 + h.wbeta))
        .collect();
    for i in 0..block.len() {
        let d = block.docs[i] as usize;
        let w = block.words[i] as usize;
        let old = block.z[i] as usize;

        let drow = &mut doc_topic[d * k..(d + 1) * k];
        let wrow = &mut word_topic[w * k..(w + 1) * k];
        drow[old] -= 1.0;
        wrow[old] -= 1.0;
        topic[old] -= 1;
        inv[old] = 1.0 / (topic[old] as f32 + h.wbeta);

        let total = fill_probs(probs, drow, wrow, &inv, h);
        let new = draw(probs, total, rng);

        drow[new] += 1.0;
        wrow[new] += 1.0;
        topic[new] += 1;
        inv[new] = 1.0 / (topic[new] as f32 + h.wbeta);
        block.z[i] = new as u32;
    }
}

/// One parallel-partition sweep: exclusive count rows, stale `n_k`
/// snapshot plus a local signed delta.
///
/// `doc_rows`/`word_rows` provide exclusive access to the rows this
/// partition owns (see [`crate::scheduler::shared::SharedRows`]).
///
/// `probs` and `inv` are caller-owned scratch: both are (re)sized and
/// fully rewritten here, so a long-lived worker (see
/// [`crate::scheduler::pool`]) can hand the same buffers to every epoch
/// and the hot path performs no per-epoch heap allocation after the
/// first call.
#[allow(clippy::too_many_arguments)]
pub fn sweep_partition<DR, WR>(
    block: &mut TokenBlock,
    mut doc_row: DR,
    mut word_row: WR,
    topic_snapshot: &[u32],
    topic_delta: &mut [i64],
    h: &Hyper,
    rng: &mut Rng,
    probs: &mut Vec<f32>,
    inv: &mut Vec<f32>,
) where
    DR: FnMut(usize) -> *mut f32,
    WR: FnMut(usize) -> *mut f32,
{
    let k = h.k;
    probs.resize(k, 0.0);
    // Reciprocal cache over the *effective* n_k (snapshot + local delta);
    // same incremental trick as sweep_serial — other workers' concurrent
    // deltas are reconciled at the epoch barrier, not here. Rebuilt in
    // place each call (the snapshot changed); `clear` + `extend` reuses
    // the allocation.
    inv.clear();
    inv.extend(
        topic_snapshot
            .iter()
            .zip(topic_delta.iter())
            .map(|(&nk, &d)| 1.0 / ((nk as i64 + d) as f32 + h.wbeta)),
    );
    for i in 0..block.len() {
        let d = block.docs[i] as usize;
        let w = block.words[i] as usize;
        let old = block.z[i] as usize;

        // SAFETY: the diagonal non-conflict property guarantees this
        // worker exclusively owns rows `d` of doc_topic and `w` of
        // word_topic for the duration of the epoch (enforced by
        // scheduler::shared::SharedRows construction).
        let (drow, wrow) = unsafe {
            (
                std::slice::from_raw_parts_mut(doc_row(d), k),
                std::slice::from_raw_parts_mut(word_row(w), k),
            )
        };
        drow[old] -= 1.0;
        wrow[old] -= 1.0;
        topic_delta[old] -= 1;
        inv[old] =
            1.0 / ((topic_snapshot[old] as i64 + topic_delta[old]) as f32 + h.wbeta);

        let total = fill_probs(probs, drow, wrow, inv, h);
        let new = draw(probs, total, rng);

        drow[new] += 1.0;
        wrow[new] += 1.0;
        topic_delta[new] += 1;
        inv[new] =
            1.0 / ((topic_snapshot[new] as i64 + topic_delta[new]) as f32 + h.wbeta);
        block.z[i] = new as u32;
    }
}

/// Fill the unnormalized conditional `p(t) = (n_jk+α)(n_kw+β)·inv(t)` and
/// return its sum. Written as lockstep iterators (no bounds checks, no
/// divisions) so LLVM vectorizes the fill; the reduction uses four
/// accumulators to break the serial float-add dependency chain.
#[inline]
fn fill_probs(probs: &mut [f32], drow: &[f32], wrow: &[f32], inv: &[f32], h: &Hyper) -> f32 {
    // Two passes: a fully vectorizable fill, then a 4-accumulator sum
    // (a fused single pass was tried and regressed — the separate fill
    // lets LLVM use wider vectors; see EXPERIMENTS.md §Perf).
    for ((p, (&dc, &wc)), &iv) in probs
        .iter_mut()
        .zip(drow.iter().zip(wrow.iter()))
        .zip(inv.iter())
    {
        *p = (dc + h.alpha) * (wc + h.beta) * iv;
    }
    let mut acc = [0.0f32; 4];
    let mut chunks = probs.chunks_exact(4);
    for c in &mut chunks {
        acc[0] += c[0];
        acc[1] += c[1];
        acc[2] += c[2];
        acc[3] += c[3];
    }
    let tail: f32 = chunks.remainder().iter().sum();
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Inverse-CDF draw from unnormalized weights with a precomputed total.
///
/// A degenerate total (all-zero weights, underflow to `0.0`, or a
/// non-finite sum) cannot drive the inverse CDF — instead of silently
/// returning the last topic, fall back to a uniform draw. A NaN total is
/// a kernel bug upstream, so it additionally trips a debug assertion.
#[inline]
pub fn draw(probs: &[f32], total: f32, rng: &mut Rng) -> usize {
    debug_assert!(!total.is_nan(), "draw: NaN weight total");
    if total.is_nan() || total <= 0.0 || total.is_infinite() {
        return rng.gen_range(probs.len());
    }
    let mut r = rng.f32_open() * total;
    for (t, &p) in probs.iter().enumerate() {
        r -= p;
        if r <= 0.0 {
            return t;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::bow::BagOfWords;
    use crate::gibbs::counts::LdaCounts;

    fn setup(k: usize, seed: u64) -> (TokenBlock, LdaCounts, Hyper, Rng) {
        let bow = BagOfWords::from_triplets(
            3,
            5,
            [(0, 0, 4), (0, 1, 2), (1, 2, 3), (2, 3, 2), (2, 4, 1)],
        );
        let mut rng = Rng::new(seed);
        let block = TokenBlock::from_corpus(&bow, k, &mut rng);
        let mut counts = LdaCounts::zeros(3, 5, k);
        counts.absorb(&block);
        (block, counts, Hyper::new(k, 0.5, 0.1, 5), rng)
    }

    #[test]
    fn serial_sweep_preserves_count_invariants() {
        let (mut block, mut counts, h, mut rng) = setup(4, 1);
        let n = counts.total();
        let mut probs = Vec::new();
        for _ in 0..10 {
            sweep_serial(
                &mut block,
                &mut counts.doc_topic,
                &mut counts.word_topic,
                &mut counts.topic,
                &h,
                &mut rng,
                &mut probs,
            );
        }
        assert_eq!(counts.total(), n);
        assert!(counts.check_consistency(&[&block]).is_ok());
    }

    #[test]
    fn partition_sweep_matches_counts_after_merge() {
        let (mut block, mut counts, h, mut rng) = setup(4, 2);
        let snapshot = counts.topic.clone();
        let mut delta = vec![0i64; 4];
        let mut probs = Vec::new();
        let mut inv = Vec::new();
        let k = h.k;
        let dt = counts.doc_topic.as_mut_ptr();
        let wt = counts.word_topic.as_mut_ptr();
        sweep_partition(
            &mut block,
            |d| unsafe { dt.add(d * k) },
            |w| unsafe { wt.add(w * k) },
            &snapshot,
            &mut delta,
            &h,
            &mut rng,
            &mut probs,
            &mut inv,
        );
        // Merge delta and verify full consistency.
        for t in 0..4 {
            let v = counts.topic[t] as i64 + delta[t];
            assert!(v >= 0);
            counts.topic[t] = v as u32;
        }
        assert!(counts.check_consistency(&[&block]).is_ok());
        // Deltas must cancel out: token count is conserved.
        assert_eq!(delta.iter().sum::<i64>(), 0);
    }

    #[test]
    fn draw_is_unbiased() {
        let mut rng = Rng::new(3);
        let probs = vec![1.0f32, 3.0];
        let mut counts = [0usize; 2];
        for _ in 0..40_000 {
            counts[draw(&probs, 4.0, &mut rng)] += 1;
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn draw_zero_total_falls_back_to_uniform() {
        // All-zero weights used to silently return the last topic; the
        // hardened draw falls back to a uniform pick over all topics.
        let mut rng = Rng::new(41);
        let probs = vec![0.0f32; 4];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[draw(&probs, 0.0, &mut rng)] += 1;
        }
        for (t, &c) in counts.iter().enumerate() {
            let frac = c as f64 / 40_000.0;
            assert!((frac - 0.25).abs() < 0.02, "topic {t}: frac {frac}");
        }
    }

    #[test]
    fn draw_infinite_total_falls_back_to_uniform() {
        let mut rng = Rng::new(43);
        let probs = vec![1.0f32, 1.0];
        let t = draw(&probs, f32::INFINITY, &mut rng);
        assert!(t < 2);
    }

    #[test]
    #[should_panic(expected = "NaN weight total")]
    #[cfg(debug_assertions)]
    fn draw_nan_total_debug_asserts() {
        let mut rng = Rng::new(47);
        draw(&[1.0f32, 1.0], f32::NAN, &mut rng);
    }

    #[test]
    fn sampler_concentrates_on_planted_structure() {
        // Two disjoint word groups used by two disjoint doc groups: after
        // a few sweeps, each document's tokens should concentrate in one
        // topic.
        let mut triplets = Vec::new();
        for d in 0..4u32 {
            for w in 0..5u32 {
                let word = if d < 2 { w } else { w + 5 };
                triplets.push((d, word, 10));
            }
        }
        let bow = BagOfWords::from_triplets(4, 10, triplets);
        let k = 2;
        let mut rng = Rng::new(7);
        let mut block = TokenBlock::from_corpus(&bow, k, &mut rng);
        let mut counts = LdaCounts::zeros(4, 10, k);
        counts.absorb(&block);
        let h = Hyper::new(k, 0.1, 0.05, 10);
        let mut probs = Vec::new();
        for _ in 0..60 {
            sweep_serial(
                &mut block,
                &mut counts.doc_topic,
                &mut counts.word_topic,
                &mut counts.topic,
                &h,
                &mut rng,
                &mut probs,
            );
        }
        // Doc 0 and doc 3 should be (nearly) pure and use different topics.
        let purity = |j: usize| {
            let row = counts.doc_row(j);
            let total: f32 = row.iter().sum();
            let max: f32 = row.iter().fold(0.0f32, |a, &b| a.max(b));
            (
                max as f64 / total as f64,
                row.iter().position(|&c| c == max),
            )
        };
        let (p0, t0) = purity(0);
        let (p3, t3) = purity(3);
        assert!(p0 > 0.9 && p3 > 0.9, "purity {p0} {p3}");
        assert_ne!(t0, t3, "disjoint word groups should map to distinct topics");
    }
}
