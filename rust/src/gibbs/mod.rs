//! Collapsed Gibbs sampling for LDA: count matrices, token storage, the
//! per-token sampling kernel, the serial reference trainer, and training
//! perplexity (paper Eq. 3–4).
//!
//! The parallel engine in [`crate::scheduler`] reuses these pieces — the
//! same kernel runs inside each conflict-free partition, with the topic
//! totals `n_k` read from an epoch snapshot and reconciled at the epoch
//! barrier (Yan et al. 2009's approximation, inherited by the paper).

pub mod counts;
pub mod perplexity;
pub mod sampler;
pub mod serial;
pub mod tokens;

pub use counts::LdaCounts;
pub use sampler::Hyper;
pub use tokens::TokenBlock;
