//! Serial (nonparallel) collapsed-Gibbs LDA trainer — the reference the
//! paper compares its parallel algorithm against, equivalent to the Java
//! GibbsLDA of Phan et al. that the authors built on.

use crate::corpus::bow::BagOfWords;
use crate::gibbs::counts::LdaCounts;
use crate::gibbs::perplexity;
use crate::gibbs::sampler::{self, Hyper};
use crate::gibbs::tokens::TokenBlock;
use crate::util::rng::Rng;

/// A serial LDA model mid-training.
pub struct SerialLda {
    pub h: Hyper,
    pub counts: LdaCounts,
    pub block: TokenBlock,
    rng: Rng,
    probs: Vec<f32>,
}

impl SerialLda {
    /// Random-initialize assignments and counts.
    pub fn init(bow: &BagOfWords, k: usize, alpha: f32, beta: f32, seed: u64) -> Self {
        let mut rng = Rng::stream(seed, 0x5E81A1);
        let block = TokenBlock::from_corpus(bow, k, &mut rng);
        let mut counts = LdaCounts::zeros(bow.num_docs(), bow.num_words(), k);
        counts.absorb(&block);
        Self {
            h: Hyper::new(k, alpha, beta, bow.num_words()),
            counts,
            block,
            rng,
            probs: Vec::new(),
        }
    }

    /// One full Gibbs sweep over every token.
    pub fn sweep(&mut self) {
        sampler::sweep_serial(
            &mut self.block,
            &mut self.counts.doc_topic,
            &mut self.counts.word_topic,
            &mut self.counts.topic,
            &self.h,
            &mut self.rng,
            &mut self.probs,
        );
    }

    /// Run `iters` sweeps, optionally recording perplexity every
    /// `eval_every` sweeps (0 = never). Returns (iteration, perplexity)
    /// pairs.
    pub fn train(
        &mut self,
        bow: &BagOfWords,
        iters: usize,
        eval_every: usize,
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for it in 1..=iters {
            self.sweep();
            if eval_every > 0 && (it % eval_every == 0 || it == iters) {
                curve.push((it, perplexity::perplexity(bow, &self.counts, &self.h)));
            }
        }
        curve
    }

    pub fn perplexity(&self, bow: &BagOfWords) -> f64 {
        perplexity::perplexity(bow, &self.counts, &self.h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, Profile};

    #[test]
    fn training_reduces_perplexity() {
        let bow = generate(&Profile::tiny(), 21);
        let mut lda = SerialLda::init(&bow, 8, 0.5, 0.1, 1);
        let p0 = lda.perplexity(&bow);
        let curve = lda.train(&bow, 30, 30);
        let p_end = curve.last().unwrap().1;
        assert!(
            p_end < p0 * 0.9,
            "perplexity should drop ≥10%: {p0} → {p_end}"
        );
    }

    #[test]
    fn counts_stay_consistent_after_training() {
        let bow = generate(&Profile::tiny(), 22);
        let mut lda = SerialLda::init(&bow, 4, 0.5, 0.1, 2);
        lda.train(&bow, 5, 0);
        assert!(lda.counts.check_consistency(&[&lda.block]).is_ok());
        assert_eq!(lda.counts.total(), bow.num_tokens());
    }

    #[test]
    fn deterministic_given_seed() {
        let bow = generate(&Profile::tiny(), 23);
        let mut a = SerialLda::init(&bow, 4, 0.5, 0.1, 9);
        let mut b = SerialLda::init(&bow, 4, 0.5, 0.1, 9);
        a.train(&bow, 3, 0);
        b.train(&bow, 3, 0);
        assert_eq!(a.block.z, b.block.z);
    }
}
