//! Token storage: struct-of-arrays blocks of (doc, word, topic) triples.
//!
//! Count matrices store cells; the Gibbs sampler walks token *instances*.
//! A [`TokenBlock`] is the sweep unit — the whole corpus for the serial
//! trainer, one `DW_mn` partition for the parallel engine.

use crate::corpus::bow::BagOfWords;
use crate::partition::scheme::Cell;
use crate::util::rng::Rng;

/// SoA block of tokens with their current topic assignments.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TokenBlock {
    pub docs: Vec<u32>,
    pub words: Vec<u32>,
    pub z: Vec<u32>,
}

impl TokenBlock {
    pub fn with_capacity(n: usize) -> Self {
        Self {
            docs: Vec::with_capacity(n),
            words: Vec::with_capacity(n),
            z: Vec::with_capacity(n),
        }
    }

    /// Expand partition cells into individual tokens with random initial
    /// topics in `0..k`.
    pub fn from_cells(cells: &[Cell], k: usize, rng: &mut Rng) -> Self {
        let n: usize = cells.iter().map(|c| c.count as usize).sum();
        let mut block = Self::with_capacity(n);
        for c in cells {
            for _ in 0..c.count {
                block.docs.push(c.doc);
                block.words.push(c.word);
                block.z.push(rng.gen_range(k) as u32);
            }
        }
        block
    }

    /// Expand a whole corpus (doc-major order) — the serial sweep unit.
    pub fn from_corpus(bow: &BagOfWords, k: usize, rng: &mut Rng) -> Self {
        let mut block = Self::with_capacity(bow.num_tokens() as usize);
        for j in 0..bow.num_docs() {
            for e in bow.doc(j) {
                for _ in 0..e.count {
                    block.docs.push(j as u32);
                    block.words.push(e.word);
                    block.z.push(rng.gen_range(k) as u32);
                }
            }
        }
        block
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.docs.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    /// Heap bytes the token arrays occupy (12 bytes/token) — the unit of
    /// the out-of-core resident-memory accounting (see
    /// [`crate::corpus::shard`]).
    #[inline]
    pub fn heap_bytes(&self) -> u64 {
        self.len() as u64 * crate::corpus::shard::BYTES_PER_TOKEN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_cells_expands_counts() {
        let cells = [
            Cell { doc: 1, word: 7, count: 3 },
            Cell { doc: 2, word: 0, count: 1 },
        ];
        let mut rng = Rng::new(1);
        let b = TokenBlock::from_cells(&cells, 4, &mut rng);
        assert_eq!(b.len(), 4);
        assert_eq!(&b.docs[..3], &[1, 1, 1]);
        assert_eq!(b.words[3], 0);
        assert!(b.z.iter().all(|&z| z < 4));
    }

    #[test]
    fn from_corpus_covers_all_tokens() {
        let bow = BagOfWords::from_triplets(2, 3, [(0, 0, 2), (1, 2, 5)]);
        let mut rng = Rng::new(2);
        let b = TokenBlock::from_corpus(&bow, 8, &mut rng);
        assert_eq!(b.len() as u64, bow.num_tokens());
        assert_eq!(b.docs.iter().filter(|&&d| d == 1).count(), 5);
    }

    #[test]
    fn heap_bytes_counts_twelve_per_token() {
        let bow = BagOfWords::from_triplets(1, 2, [(0, 0, 3), (0, 1, 2)]);
        let mut rng = Rng::new(4);
        let b = TokenBlock::from_corpus(&bow, 2, &mut rng);
        assert_eq!(b.heap_bytes(), 5 * 12);
        assert_eq!(TokenBlock::default().heap_bytes(), 0);
    }

    #[test]
    fn initial_topics_cover_range() {
        let bow = BagOfWords::from_triplets(1, 1, [(0, 0, 1000)]);
        let mut rng = Rng::new(3);
        let b = TokenBlock::from_corpus(&bow, 4, &mut rng);
        let mut seen = [false; 4];
        for &z in &b.z {
            seen[z as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
