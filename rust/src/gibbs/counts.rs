//! LDA count matrices: document–topic `Cθ` (`n_jk`), word–topic `Cφ`
//! (`n_kw`, stored word-major for contiguous per-word rows), and topic
//! totals `n_k`.
//!
//! Rows are flat `[K]` slices so the sampling kernel walks contiguous
//! memory; the parallel engine hands disjoint row sets to workers (see
//! [`crate::scheduler::shared`]).

use crate::gibbs::tokens::TokenBlock;

/// Cell count type of the dense matrices: f32. Counts are integers far
/// below 2^24, so f32 is exact, and the sampling kernel's hot loop avoids
/// a u32→f32 convert per element (EXPERIMENTS.md §Perf iteration 4).
pub type Count = f32;

#[derive(Clone, Debug)]
pub struct LdaCounts {
    pub k: usize,
    pub num_docs: usize,
    pub num_words: usize,
    /// `n_jk`, row-major `[num_docs][k]`.
    pub doc_topic: Vec<Count>,
    /// `n_kw` stored word-major: `[num_words][k]`.
    pub word_topic: Vec<Count>,
    /// `n_k` topic totals over word tokens.
    pub topic: Vec<u32>,
}

impl LdaCounts {
    pub fn zeros(num_docs: usize, num_words: usize, k: usize) -> Self {
        Self {
            k,
            num_docs,
            num_words,
            doc_topic: vec![0.0; num_docs * k],
            word_topic: vec![0.0; num_words * k],
            topic: vec![0; k],
        }
    }

    /// Accumulate the assignments of one token block.
    pub fn absorb(&mut self, block: &TokenBlock) {
        for i in 0..block.len() {
            let (d, w, z) = (
                block.docs[i] as usize,
                block.words[i] as usize,
                block.z[i] as usize,
            );
            self.doc_topic[d * self.k + z] += 1.0;
            self.word_topic[w * self.k + z] += 1.0;
            self.topic[z] += 1;
        }
    }

    #[inline]
    pub fn doc_row(&self, j: usize) -> &[Count] {
        &self.doc_topic[j * self.k..(j + 1) * self.k]
    }

    #[inline]
    pub fn word_row(&self, w: usize) -> &[Count] {
        &self.word_topic[w * self.k..(w + 1) * self.k]
    }

    /// Document length implied by the counts (token count of doc j).
    pub fn doc_len(&self, j: usize) -> u64 {
        self.doc_row(j).iter().map(|&c| c as u64).sum()
    }

    /// Total tokens across topics — sanity invariant.
    pub fn total(&self) -> u64 {
        self.topic.iter().map(|&c| c as u64).sum()
    }

    /// Exhaustive consistency check against token blocks (test helper —
    /// O(N + (D+W)K)).
    pub fn check_consistency(&self, blocks: &[&TokenBlock]) -> Result<(), String> {
        let mut expect = LdaCounts::zeros(self.num_docs, self.num_words, self.k);
        for b in blocks {
            expect.absorb(b);
        }
        if expect.doc_topic != self.doc_topic {
            return Err("doc_topic mismatch".into());
        }
        if expect.word_topic != self.word_topic {
            return Err("word_topic mismatch".into());
        }
        if expect.topic != self.topic {
            return Err("topic totals mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block() -> TokenBlock {
        TokenBlock {
            docs: vec![0, 0, 1],
            words: vec![2, 2, 0],
            z: vec![1, 1, 0],
        }
    }

    #[test]
    fn absorb_accumulates() {
        let mut c = LdaCounts::zeros(2, 3, 2);
        c.absorb(&block());
        assert_eq!(c.doc_row(0), &[0.0, 2.0]);
        assert_eq!(c.doc_row(1), &[1.0, 0.0]);
        assert_eq!(c.word_row(2), &[0.0, 2.0]);
        assert_eq!(c.word_row(0), &[1.0, 0.0]);
        assert_eq!(c.topic, vec![1, 2]);
        assert_eq!(c.total(), 3);
        assert_eq!(c.doc_len(0), 2);
    }

    #[test]
    fn consistency_detects_corruption() {
        let mut c = LdaCounts::zeros(2, 3, 2);
        let b = block();
        c.absorb(&b);
        assert!(c.check_consistency(&[&b]).is_ok());
        c.topic[0] += 1;
        assert!(c.check_consistency(&[&b]).is_err());
    }

    #[test]
    fn consistency_names_each_corrupted_matrix() {
        // The post-sweep debug assertion in the parallel trainers
        // surfaces these messages; each matrix must be distinguishable
        // so a kernel count-delta bug points at the right structure.
        let b = block();

        let mut c = LdaCounts::zeros(2, 3, 2);
        c.absorb(&b);
        c.doc_topic[0] += 1.0;
        assert_eq!(c.check_consistency(&[&b]).unwrap_err(), "doc_topic mismatch");

        let mut c = LdaCounts::zeros(2, 3, 2);
        c.absorb(&b);
        c.word_topic[1] -= 1.0;
        assert_eq!(c.check_consistency(&[&b]).unwrap_err(), "word_topic mismatch");

        let mut c = LdaCounts::zeros(2, 3, 2);
        c.absorb(&b);
        c.topic[1] -= 1;
        assert_eq!(c.check_consistency(&[&b]).unwrap_err(), "topic totals mismatch");
    }

    #[test]
    fn consistency_detects_swapped_assignments() {
        // Counts that are right in aggregate but attached to the wrong
        // block assignments must still fail: the check recomputes from
        // the blocks' z, so a block/counts divergence is caught.
        let mut c = LdaCounts::zeros(2, 3, 2);
        let mut b = block();
        c.absorb(&b);
        b.z[0] = 0; // was 1; counts still reflect the old assignment
        assert!(c.check_consistency(&[&b]).is_err());
    }
}
