//! Observability: structured tracing and metrics for the partitioned
//! trainer.
//!
//! - [`trace`] — zero-cost-when-off per-task spans and events in
//!   lock-free per-lane ring buffers ([`trace::Tracer`]), drained at
//!   sweep boundaries.
//! - [`metrics`] — counters, gauges, log-bucketed histograms, and the
//!   phase-time [`metrics::Registry`] that `SweepStats` second-buckets
//!   and the report `PhaseTimer` are views over.
//! - [`export`] — Chrome-trace/Perfetto JSON and JSONL writers plus a
//!   lossless reader.
//! - [`analyze`] — the `pplda analyze-trace` engine: span-schema
//!   validation, per-sweep critical path, idle gaps, steal
//!   effectiveness, and measured-η recomputed from raw spans.
//!
//! Tracing is strictly observational: no sampling decision ever reads
//! it, so tracing on ≡ tracing off bit-for-bit (pinned by the matrix
//! tests in `scheduler::exec`). See `docs/observability.md` for the
//! event taxonomy, span schema, and overhead contract.

pub mod analyze;
pub mod export;
pub mod metrics;
pub mod trace;

pub use export::TraceMeta;
pub use metrics::{Family, Phase, Registry};
pub use trace::{Event, EventKind, Tracer};
