//! Metrics registry: counters, gauges, log-bucketed histograms, and the
//! per-phase wallclock accounts that `SweepStats` and the train reports
//! are views over.
//!
//! Everything here is lock-free (`AtomicU64`, relaxed ordering) so the
//! trainers can record through `&self` while telemetry readers snapshot
//! concurrently. Determinism is untouched by construction: metrics only
//! *observe* — no sampling decision ever reads them.
//!
//! # One clock, one truth
//!
//! Before this module, the per-sweep `SweepStats` second-buckets and the
//! drivers' `PhaseTimer` kept parallel books over the same measurements.
//! Now the trainer records each phase measurement exactly once into a
//! [`Registry`] ([`Registry::add_phase`]); `SweepStats` fields are
//! per-sweep deltas of those accounts ([`Registry::phase_snapshot`] /
//! [`Registry::delta_secs`]) and the report's phase breakdown is the
//! cumulative view ([`Registry::phases_secs`]) — same names, same
//! values, single source. See `docs/observability.md`.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

use crate::util::timer::PhaseTimer;

/// A monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// A last-written-value gauge (e.g. resident bytes).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Relaxed);
    }

    /// Raise the gauge to `v` if larger (high-water marks).
    #[inline]
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Sub-buckets per power-of-two octave: values within an octave resolve
/// to 8 geometric steps, bounding the relative quantile error at ~1/8.
const SUB: usize = 8;
const SUB_BITS: u32 = 3;
/// Values `0..8` get exact unit buckets; octaves 3..=63 get [`SUB`]
/// buckets each.
const BUCKETS: usize = SUB + 61 * SUB;

#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        let o = 63 - v.leading_zeros() as usize;
        let sub = ((v >> (o as u32 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        (o - 2) * SUB + sub
    }
}

/// Inclusive lower bound of bucket `idx`.
#[inline]
fn bucket_lo(idx: usize) -> u64 {
    if idx < SUB {
        idx as u64
    } else {
        let o = idx / SUB + 2;
        let sub = (idx % SUB) as u64;
        (1u64 << o) + (sub << (o as u32 - SUB_BITS))
    }
}

/// The value a bucket reports for quantiles: its geometric midpoint.
#[inline]
fn bucket_mid(idx: usize) -> u64 {
    if idx < SUB {
        idx as u64
    } else {
        let o = idx / SUB + 2;
        let width = 1u64 << (o as u32 - SUB_BITS);
        bucket_lo(idx) + width / 2
    }
}

/// A log-bucketed histogram over `u64` samples (nanoseconds in
/// practice): 8 sub-buckets per power-of-two octave, so `p50`/`p95`/
/// `p99` are answered in O(buckets) with a bounded ~6% relative error,
/// at a fixed 4 KiB of `AtomicU64` state. Concurrent `observe` is safe
/// from any thread; merging across per-worker instances is bucket-wise
/// addition ([`Histogram::merge_from`]).
#[derive(Debug)]
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// The `q`-quantile (`0.0..=1.0`) as the geometric midpoint of the
    /// bucket holding the rank-`⌈q·n⌉` sample; 0 when empty. The exact
    /// max is reported for `q == 1.0`.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        if q >= 1.0 {
            return self.max();
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Relaxed);
            if seen >= rank {
                return bucket_mid(i);
            }
        }
        self.max()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Fold another histogram's samples into this one (bucket-wise
    /// addition — the cross-worker merge).
    pub fn merge_from(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = b.load(Relaxed);
            if n > 0 {
                a.fetch_add(n, Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Relaxed);
        self.sum.fetch_add(other.sum(), Relaxed);
        self.max.fetch_max(other.max(), Relaxed);
    }
}

/// The canonical phase buckets of a training run. Names are the stable
/// report/JSON keys the pre-registry `PhaseTimer` used — do not rename.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Phase {
    Sample = 0,
    Barrier,
    Update,
    Commit,
    Runahead,
    SpillLoad,
    SpillWrite,
    Checkpoint,
    Perplexity,
}

/// All phases in canonical report order.
pub const PHASES: [Phase; 9] = [
    Phase::Sample,
    Phase::Barrier,
    Phase::Update,
    Phase::Commit,
    Phase::Runahead,
    Phase::SpillLoad,
    Phase::SpillWrite,
    Phase::Checkpoint,
    Phase::Perplexity,
];

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Sample => "sample",
            Phase::Barrier => "barrier",
            Phase::Update => "update",
            Phase::Commit => "commit",
            Phase::Runahead => "runahead",
            Phase::SpillLoad => "spill_load",
            Phase::SpillWrite => "spill_write",
            Phase::Checkpoint => "checkpoint",
            Phase::Perplexity => "perplexity",
        }
    }
}

/// Which trainer phase family an account belongs to: LDA (and the BoT
/// word phase) vs the BoT timestamp phase. Keeping the two families
/// separate lets BoT's `wstats`/`sstats` both be registry views while
/// the report sums them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Family {
    Word = 0,
    Stamp = 1,
}

const N_PHASES: usize = PHASES.len();
const N_FAMILIES: usize = 2;

/// A point-in-time copy of the registry's phase accounts, used to
/// compute per-sweep deltas (the `SweepStats` view).
#[derive(Clone, Debug)]
pub struct PhaseSnapshot([[u64; N_PHASES]; N_FAMILIES]);

/// The trainer-owned metrics registry: phase wallclock accounts (nanos),
/// fault/balance counters, the per-task duration histogram, and memory
/// gauges. One instance per trainer; the driver reads it for the report.
#[derive(Debug)]
pub struct Registry {
    phase_ns: [[AtomicU64; N_PHASES]; N_FAMILIES],
    /// Sweeps recorded (gates the always-present phase buckets in
    /// [`Self::phases_secs`] so untouched registries render empty).
    pub sweeps: Counter,
    /// Tasks executed (one per partition per epoch).
    pub tasks: Counter,
    /// Tasks re-executed after contained panics.
    pub task_retries: Counter,
    /// Transient spill-IO retries absorbed.
    pub io_retries: Counter,
    /// Checkpoints committed.
    pub checkpoints: Counter,
    /// Serial-equivalent busy nanos per family (measured-η numerator).
    busy_ns: [Counter; N_FAMILIES],
    /// Measured critical-path nanos per family (Σ_epoch max_worker).
    crit_ns: [Counter; N_FAMILIES],
    /// Measured per-task sweep nanos across all workers and sweeps.
    pub task_ns: Histogram,
    /// Last observed resident + in-flight token bytes (spill mode).
    pub resident_bytes: Gauge,
    /// High-water mark of `resident_bytes`.
    pub peak_resident_bytes: Gauge,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self {
            phase_ns: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
            sweeps: Counter::new(),
            tasks: Counter::new(),
            task_retries: Counter::new(),
            io_retries: Counter::new(),
            checkpoints: Counter::new(),
            busy_ns: std::array::from_fn(|_| Counter::new()),
            crit_ns: std::array::from_fn(|_| Counter::new()),
            task_ns: Histogram::new(),
            resident_bytes: Gauge::new(),
            peak_resident_bytes: Gauge::new(),
        }
    }

    #[inline]
    pub fn add_phase(&self, family: Family, phase: Phase, d: Duration) {
        self.add_phase_nanos(family, phase, d.as_nanos() as u64);
    }

    #[inline]
    pub fn add_phase_secs(&self, family: Family, phase: Phase, secs: f64) {
        if secs > 0.0 {
            self.add_phase_nanos(family, phase, (secs * 1e9) as u64);
        }
    }

    #[inline]
    pub fn add_phase_nanos(&self, family: Family, phase: Phase, ns: u64) {
        self.phase_ns[family as usize][phase as usize].fetch_add(ns, Relaxed);
    }

    pub fn phase_nanos(&self, family: Family, phase: Phase) -> u64 {
        self.phase_ns[family as usize][phase as usize].load(Relaxed)
    }

    /// Phase account summed over both families.
    pub fn phase_total_nanos(&self, phase: Phase) -> u64 {
        (0..N_FAMILIES)
            .map(|f| self.phase_ns[f][phase as usize].load(Relaxed))
            .sum()
    }

    /// Snapshot every phase account — taken at sweep start so the sweep
    /// can report its increments as `SweepStats` seconds.
    pub fn phase_snapshot(&self) -> PhaseSnapshot {
        PhaseSnapshot(std::array::from_fn(|f| {
            std::array::from_fn(|p| self.phase_ns[f][p].load(Relaxed))
        }))
    }

    /// Seconds accumulated in `(family, phase)` since `snap`.
    pub fn delta_secs(&self, snap: &PhaseSnapshot, family: Family, phase: Phase) -> f64 {
        let now = self.phase_ns[family as usize][phase as usize].load(Relaxed);
        (now - snap.0[family as usize][phase as usize]) as f64 / 1e9
    }

    /// Record one sweep's measured-η inputs for `family`.
    pub fn observe_eta(&self, family: Family, busy_ns: u64, crit_ns: u64) {
        self.busy_ns[family as usize].add(busy_ns);
        self.crit_ns[family as usize].add(crit_ns);
    }

    pub fn busy_nanos(&self, family: Family) -> u64 {
        self.busy_ns[family as usize].get()
    }

    pub fn crit_nanos(&self, family: Family) -> u64 {
        self.crit_ns[family as usize].get()
    }

    /// Measured-η over everything recorded for `family`:
    /// `busy / (workers · crit)`; 1.0 when nothing was measured.
    pub fn measured_eta(&self, family: Family, workers: usize) -> f64 {
        let crit = self.crit_nanos(family);
        if crit == 0 {
            return 1.0;
        }
        self.busy_nanos(family) as f64 / (workers.max(1) as f64 * crit as f64)
    }

    /// The report phase breakdown, families summed, in canonical order.
    /// The always-measured buckets (sample/barrier/update) appear
    /// whenever any sweep was recorded; conditional buckets (commit,
    /// runahead, spill/checkpoint/perplexity) appear only when non-zero
    /// — exactly the presence rules the pre-registry drivers had. An
    /// untouched registry (serial runs) renders empty.
    pub fn phases_secs(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        if self.sweeps.get() == 0 {
            return out;
        }
        for ph in PHASES {
            let ns = self.phase_total_nanos(ph);
            let always = matches!(ph, Phase::Sample | Phase::Barrier | Phase::Update);
            if always || ns > 0 {
                out.push((ph.name().to_string(), ns as f64 / 1e9));
            }
        }
        out
    }

    /// The cumulative phase view as a [`PhaseTimer`] — what drivers used
    /// to accumulate by hand.
    pub fn phase_timer(&self) -> PhaseTimer {
        PhaseTimer::from_secs(self.phases_secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_powers_and_subs() {
        // Small values are exact.
        for v in 0..8u64 {
            assert_eq!(bucket_lo(bucket_index(v)), v);
            assert_eq!(bucket_mid(bucket_index(v)), v);
        }
        // Octave starts land on their own bucket's lower bound.
        for o in 3..=62u32 {
            let v = 1u64 << o;
            let idx = bucket_index(v);
            assert_eq!(bucket_lo(idx), v, "octave {o}");
            // Last value before the octave lives in the previous bucket.
            assert_ne!(bucket_index(v - 1), idx, "octave {o}");
        }
        // Sub-bucket width is 1/8 of the octave.
        let idx16 = bucket_index(16);
        assert_eq!(bucket_index(17), idx16, "width-2 bucket at 16");
        assert_ne!(bucket_index(18), idx16);
        // Values 8..16 remain exact (width-1 buckets).
        for v in 8..16u64 {
            assert_eq!(bucket_lo(bucket_index(v)), v);
        }
        // Monotone, in-bounds.
        let mut prev = 0;
        for v in [0u64, 1, 7, 8, 100, 1_000, 1 << 20, 1 << 40, u64::MAX] {
            let idx = bucket_index(v);
            assert!(idx < BUCKETS);
            assert!(idx >= prev, "non-monotone at {v}");
            prev = idx;
        }
    }

    #[test]
    fn quantiles_on_known_distributions() {
        // Uniform 1..=1000: p50 ≈ 500, p99 ≈ 990, within bucket error.
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.observe(v);
        }
        let p50 = h.p50() as f64;
        let p99 = h.p99() as f64;
        assert!((p50 - 500.0).abs() / 500.0 < 0.10, "p50 {p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.10, "p99 {p99}");
        assert_eq!(h.count(), 1000);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.quantile(1.0), 1000);
        assert!((h.mean() - 500.5).abs() < 1.0);

        // Bimodal: 90% at ~100, 10% at ~100_000 — p50 in the low mode,
        // p95/p99 in the high one.
        let h = Histogram::new();
        for _ in 0..900 {
            h.observe(100);
        }
        for _ in 0..100 {
            h.observe(100_000);
        }
        assert!((h.p50() as f64 - 100.0).abs() / 100.0 < 0.10, "{}", h.p50());
        assert!(
            (h.p99() as f64 - 100_000.0).abs() / 100_000.0 < 0.10,
            "{}",
            h.p99()
        );

        // Degenerate: constant distribution.
        let h = Histogram::new();
        for _ in 0..50 {
            h.observe(42);
        }
        let p = h.p50() as f64;
        assert!((p - 42.0).abs() / 42.0 < 0.07, "{p}");
        assert_eq!(h.quantile(0.0), h.quantile(0.01));

        // Empty histogram answers zeros.
        let h = Histogram::new();
        assert_eq!(h.p50(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn merge_across_workers_matches_single_stream() {
        let merged = Histogram::new();
        let whole = Histogram::new();
        let parts: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
        for v in 0..4000u64 {
            let x = (v * 2654435761) % 1_000_000;
            parts[(v % 4) as usize].observe(x);
            whole.observe(x);
        }
        for p in &parts {
            merged.merge_from(p);
        }
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.sum(), whole.sum());
        assert_eq!(merged.max(), whole.max());
        for q in [0.1, 0.5, 0.95, 0.99] {
            assert_eq!(merged.quantile(q), whole.quantile(q), "q={q}");
        }
    }

    #[test]
    fn histogram_concurrent_observe() {
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..1000u64 {
                        h.observe(t * 1000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn registry_phase_accounts_and_views() {
        let reg = Registry::new();
        assert!(reg.phases_secs().is_empty(), "untouched registry is empty");
        reg.sweeps.inc();
        reg.add_phase(Family::Word, Phase::Sample, Duration::from_millis(30));
        reg.add_phase(Family::Stamp, Phase::Sample, Duration::from_millis(10));
        reg.add_phase(Family::Word, Phase::Barrier, Duration::from_millis(5));
        let ph = reg.phases_secs();
        let names: Vec<&str> = ph.iter().map(|(n, _)| n.as_str()).collect();
        // Always-present buckets appear (update at 0.0), conditional
        // ones only when non-zero.
        assert_eq!(names, vec!["sample", "barrier", "update"]);
        let sample = ph.iter().find(|(n, _)| n == "sample").unwrap().1;
        assert!((sample - 0.040).abs() < 1e-6, "families sum: {sample}");

        reg.add_phase(Family::Word, Phase::Commit, Duration::from_millis(2));
        reg.add_phase(Family::Word, Phase::Perplexity, Duration::from_millis(1));
        let names: Vec<String> = reg.phases_secs().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["sample", "barrier", "update", "commit", "perplexity"]);

        // Per-sweep delta view (the SweepStats contract).
        let snap = reg.phase_snapshot();
        reg.add_phase(Family::Word, Phase::Sample, Duration::from_millis(7));
        assert!((reg.delta_secs(&snap, Family::Word, Phase::Sample) - 0.007).abs() < 1e-6);
        assert_eq!(reg.delta_secs(&snap, Family::Stamp, Phase::Sample), 0.0);

        // PhaseTimer view mirrors phases_secs.
        let t = reg.phase_timer();
        assert!(t.get("sample").as_secs_f64() > 0.0);
    }

    #[test]
    fn registry_measured_eta() {
        let reg = Registry::new();
        assert_eq!(reg.measured_eta(Family::Word, 4), 1.0);
        reg.observe_eta(Family::Word, 800, 250);
        assert!((reg.measured_eta(Family::Word, 4) - 0.8).abs() < 1e-12);
        reg.observe_eta(Family::Stamp, 100, 100);
        assert!((reg.measured_eta(Family::Stamp, 1) - 1.0).abs() < 1e-12);
    }
}
