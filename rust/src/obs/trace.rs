//! Structured tracing: per-task spans and events recorded into
//! lock-free per-lane ring buffers, drained at sweep boundaries.
//!
//! # Design
//!
//! A [`Tracer`] owns one single-producer/single-consumer ring per
//! *lane*. Lanes are timelines: one per worker (`0..workers`), one for
//! the coordinator thread ([`Tracer::coord_lane`]), and one for the IO
//! timeline ([`Tracer::io_lane`]). The SPSC invariant is upheld by
//! construction, not by locks:
//!
//! - worker lane `w` is written only by the thread currently executing
//!   worker `w`'s tasks (scoped thread, pool worker, or — for
//!   `SequentialExec` — the coordinator itself, which visits lanes one
//!   at a time);
//! - the coordinator and IO lanes are written only by the coordinator
//!   thread (IO durations are measured around `acquire`/`release`/
//!   `prefetch` calls; the prefetcher's own thread never touches the
//!   tracer);
//! - draining happens at sweep boundaries, when every executor has
//!   joined/parked its workers, and is additionally serialized by the
//!   sink mutex.
//!
//! A full ring drops the event and counts it ([`Tracer::dropped`])
//! rather than blocking or reallocating — tracing must never perturb
//! the schedule. Determinism is structural: the tracer only *observes*
//! (no sampling decision ever reads it), so tracing on ≡ tracing off
//! bit-for-bit; the matrix tests pin this.
//!
//! # Overhead contract
//!
//! Tracing **off** (`trace: None` in `TaskObs`): the per-task cost is
//! one `Option` test on an already-loaded struct field — no timestamp,
//! no atomic. Tracing **on**: two `Instant` reads and one ring push
//! (~3 relaxed/acq-rel atomics) per event. See `docs/observability.md`.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default per-lane ring capacity (events). A sweep drains the rings,
/// so this bounds events per lane per sweep, not per run.
pub const DEFAULT_LANE_CAP: usize = 1 << 15;

/// What an [`Event`] records. Span kinds carry a duration; instant
/// kinds mark a point; `ResidentBytes` is a counter sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// One full sweep (coordinator lane).
    Sweep = 0,
    /// One diagonal epoch (coordinator lane).
    Epoch,
    /// One task's sampling span (worker lane; `dur_ns` is the same
    /// measured duration `SweepStats::task_nanos` records).
    Task,
    /// Time a pool worker waited for its next job (worker lane).
    QueueWait,
    /// A task executed from the steal queue rather than its owner's
    /// static list (worker lane, instant; `arg` = task nanos).
    Steal,
    /// A ticketed in-order delta fold (coordinator lane; `arg` =
    /// in-flight tasks at fold time — 0 means the committer blocked).
    Commit,
    /// Barrier-mode gather/merge of an epoch's deltas (coordinator).
    Barrier,
    /// A contained task panic rolled back (instant; `arg` = attempt).
    Rollback,
    /// A task re-execution attempt after a rollback (instant; `arg` =
    /// attempt number).
    Retry,
    /// Spill-block load wait on the sampling path (IO lane).
    IoLoad,
    /// Spill-block writeback wait (IO lane).
    IoWrite,
    /// Transient spill-IO retries absorbed this sweep (instant; `arg`
    /// = retry count delta).
    IoRetry,
    /// Prefetch issued for a diagonal (IO lane, instant; `partition`
    /// = diagonal index).
    Prefetch,
    /// Sampled resident + in-flight token bytes (counter; `arg` =
    /// bytes).
    ResidentBytes,
    /// One atomic checkpoint write (coordinator lane).
    Checkpoint,
}

impl EventKind {
    pub const ALL: [EventKind; 15] = [
        EventKind::Sweep,
        EventKind::Epoch,
        EventKind::Task,
        EventKind::QueueWait,
        EventKind::Steal,
        EventKind::Commit,
        EventKind::Barrier,
        EventKind::Rollback,
        EventKind::Retry,
        EventKind::IoLoad,
        EventKind::IoWrite,
        EventKind::IoRetry,
        EventKind::Prefetch,
        EventKind::ResidentBytes,
        EventKind::Checkpoint,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EventKind::Sweep => "sweep",
            EventKind::Epoch => "epoch",
            EventKind::Task => "task",
            EventKind::QueueWait => "queue_wait",
            EventKind::Steal => "steal",
            EventKind::Commit => "commit",
            EventKind::Barrier => "barrier",
            EventKind::Rollback => "rollback",
            EventKind::Retry => "retry",
            EventKind::IoLoad => "io_load",
            EventKind::IoWrite => "io_write",
            EventKind::IoRetry => "io_retry",
            EventKind::Prefetch => "prefetch",
            EventKind::ResidentBytes => "resident_bytes",
            EventKind::Checkpoint => "checkpoint",
        }
    }

    pub fn parse(s: &str) -> Option<EventKind> {
        EventKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// Span kinds render as Chrome complete (`ph:"X"`) events; instants
    /// as `ph:"i"`; `ResidentBytes` as a counter (`ph:"C"`).
    pub fn is_span(self) -> bool {
        matches!(
            self,
            EventKind::Sweep
                | EventKind::Epoch
                | EventKind::Task
                | EventKind::QueueWait
                | EventKind::Commit
                | EventKind::Barrier
                | EventKind::IoLoad
                | EventKind::IoWrite
                | EventKind::Checkpoint
        )
    }
}

/// One fixed-size trace record. `Copy` so ring slots never allocate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub kind: EventKind,
    /// Phase family: 0 = word (LDA), 1 = stamp (BoT phase two).
    pub family: u8,
    /// Timeline index: worker id, or the coordinator/IO lanes.
    pub lane: u16,
    pub sweep: u32,
    /// Diagonal epoch within the sweep.
    pub epoch: u32,
    /// Task index within the epoch (commit order).
    pub ticket: u32,
    /// Partition id (`ids[ticket]`), or a kind-specific index.
    pub partition: u64,
    /// Nanoseconds since the tracer's time base.
    pub t0_ns: u64,
    /// Span duration in nanoseconds (0 for instants).
    pub dur_ns: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub arg: u64,
}

impl Event {
    /// A zeroed template; call sites fill fields with struct-update
    /// syntax: `Event { lane, t0_ns, .. Event::of(EventKind::Task) }`.
    pub fn of(kind: EventKind) -> Event {
        Event {
            kind,
            family: 0,
            lane: 0,
            sweep: 0,
            epoch: 0,
            ticket: 0,
            partition: 0,
            t0_ns: 0,
            dur_ns: 0,
            arg: 0,
        }
    }
}

/// A bounded SPSC ring. Exactly one thread pushes (the lane's current
/// owner) and one thread drains (the coordinator, under the sink
/// mutex); `head`/`tail` are free-running counters masked into the
/// power-of-two slot array.
struct Ring {
    slots: Box<[UnsafeCell<Event>]>,
    mask: usize,
    /// Next write position; owned by the producer, Release-published.
    head: AtomicUsize,
    /// Next read position; owned by the consumer, Release-published.
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: slot `i` is written only by the single producer while
// `i ∉ [tail, head)` (i.e. not yet published) and read only by the
// single consumer after the Release store of `head` made the write
// visible (Acquire load in `drain_into`). Producer/consumer roles are
// exclusive per lane by construction (module docs).
unsafe impl Sync for Ring {}

impl Ring {
    fn new(cap: usize) -> Ring {
        let cap = cap.next_power_of_two().max(64);
        Ring {
            slots: (0..cap).map(|_| UnsafeCell::new(Event::of(EventKind::Sweep))).collect(),
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    #[inline]
    fn push(&self, ev: Event) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) > self.mask {
            // Full: drop and count rather than block the sampler.
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        unsafe { *self.slots[head & self.mask].get() = ev };
        self.head.store(head.wrapping_add(1), Ordering::Release);
    }

    fn drain_into(&self, out: &mut Vec<Event>) {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        while tail != head {
            out.push(unsafe { *self.slots[tail & self.mask].get() });
            tail = tail.wrapping_add(1);
        }
        self.tail.store(tail, Ordering::Release);
    }
}

/// The trace recorder: per-lane rings plus a coordinator-drained sink.
/// Shared by reference into the executors (`TaskObs`); `emit` is safe
/// from any lane's producer thread.
pub struct Tracer {
    t0: Instant,
    workers: usize,
    lanes: Vec<Ring>,
    sink: Mutex<Vec<Event>>,
}

impl Tracer {
    pub fn new(workers: usize) -> Tracer {
        Tracer::with_capacity(workers, DEFAULT_LANE_CAP)
    }

    pub fn with_capacity(workers: usize, lane_cap: usize) -> Tracer {
        let workers = workers.max(1);
        Tracer {
            t0: Instant::now(),
            workers,
            lanes: (0..workers + 2).map(|_| Ring::new(lane_cap)).collect(),
            sink: Mutex::new(Vec::new()),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// The coordinator thread's timeline (sweep/epoch/commit/barrier/
    /// checkpoint spans).
    pub fn coord_lane(&self) -> u16 {
        self.workers as u16
    }

    /// The IO timeline (spill load/write waits, prefetch issues,
    /// resident-bytes samples).
    pub fn io_lane(&self) -> u16 {
        (self.workers + 1) as u16
    }

    /// Nanoseconds since the tracer's time base.
    #[inline]
    pub fn now(&self) -> u64 {
        self.t0.elapsed().as_nanos() as u64
    }

    /// Record `ev` on its `lane`'s ring. Caller must be the lane's
    /// current producer (module docs); out-of-range lanes are counted
    /// as drops on lane 0.
    #[inline]
    pub fn emit(&self, ev: Event) {
        match self.lanes.get(ev.lane as usize) {
            Some(ring) => ring.push(ev),
            None => self.lanes[0].dropped.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Move all ring contents into the sink. Call at sweep boundaries,
    /// when workers are parked/joined.
    pub fn drain(&self) {
        let mut sink = self.sink.lock().unwrap();
        for ring in &self.lanes {
            ring.drain_into(&mut sink);
        }
    }

    /// Events dropped to full rings so far (0 in healthy runs).
    pub fn dropped(&self) -> u64 {
        self.lanes.iter().map(|r| r.dropped.load(Ordering::Relaxed)).sum()
    }

    /// Final drain + take: every event recorded so far, sorted by
    /// `(t0_ns, lane)` into one timeline. Leaves the sink empty.
    pub fn take(&self) -> Vec<Event> {
        self.drain();
        let mut out = std::mem::take(&mut *self.sink.lock().unwrap());
        out.sort_by_key(|e| (e.t0_ns, e.lane, e.kind as u8));
        out
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("workers", &self.workers)
            .field("lanes", &self.lanes.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drain_preserves_order_no_loss_no_dup() {
        let tr = Tracer::with_capacity(2, 1 << 10);
        for i in 0..100u64 {
            tr.emit(Event {
                lane: (i % 2) as u16,
                partition: i,
                t0_ns: i,
                ..Event::of(EventKind::Task)
            });
        }
        let evs = tr.take();
        assert_eq!(evs.len(), 100);
        assert_eq!(tr.dropped(), 0);
        let mut seen: Vec<u64> = evs.iter().map(|e| e.partition).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
        // Second take is empty (no duplication).
        assert!(tr.take().is_empty());
    }

    #[test]
    fn ring_full_drops_and_counts_instead_of_blocking() {
        let tr = Tracer::with_capacity(1, 64);
        for i in 0..200u64 {
            tr.emit(Event { partition: i, ..Event::of(EventKind::Task) });
        }
        assert_eq!(tr.dropped(), 200 - 64);
        let evs = tr.take();
        assert_eq!(evs.len(), 64);
        // The *oldest* events survive (drop-newest policy).
        assert_eq!(evs[0].partition, 0);
    }

    #[test]
    fn drain_between_pushes_wraps_ring_without_loss() {
        let tr = Tracer::with_capacity(1, 64);
        let mut total = 0u64;
        for round in 0..10u64 {
            for i in 0..50u64 {
                tr.emit(Event { partition: round * 50 + i, ..Event::of(EventKind::Task) });
            }
            tr.drain();
            total += 50;
        }
        let evs = tr.take();
        assert_eq!(evs.len() as u64, total);
        assert_eq!(tr.dropped(), 0);
        let mut parts: Vec<u64> = evs.iter().map(|e| e.partition).collect();
        parts.sort_unstable();
        assert_eq!(parts, (0..total).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_producers_one_per_lane() {
        let tr = Tracer::with_capacity(4, 1 << 12);
        std::thread::scope(|s| {
            for lane in 0..4u16 {
                let tr = &tr;
                s.spawn(move || {
                    for i in 0..2000u64 {
                        tr.emit(Event {
                            lane,
                            partition: lane as u64 * 10_000 + i,
                            ..Event::of(EventKind::Task)
                        });
                    }
                });
            }
        });
        let evs = tr.take();
        assert_eq!(evs.len(), 8000);
        assert_eq!(tr.dropped(), 0);
        for lane in 0..4u16 {
            let mut parts: Vec<u64> = evs
                .iter()
                .filter(|e| e.lane == lane)
                .map(|e| e.partition)
                .collect();
            parts.sort_unstable();
            let want: Vec<u64> = (0..2000).map(|i| lane as u64 * 10_000 + i).collect();
            assert_eq!(parts, want, "lane {lane}");
        }
    }

    #[test]
    fn event_kind_names_round_trip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::parse(k.name()), Some(k));
        }
        assert_eq!(EventKind::parse("nope"), None);
    }
}
