//! Trace analyzer backing `pplda analyze-trace`: schema validation
//! (every scheduled task appears exactly once), per-sweep critical-path
//! reconstruction, per-worker busy/idle timelines, steal
//! effectiveness, latency quantiles, and a measured-η recomputed from
//! raw task spans — cross-checkable against the trainer's own
//! `measured_eta` (same accounting: busy / (workers · Σ_epoch max-lane
//! busy)).

use std::collections::BTreeMap;

use crate::obs::export::TraceMeta;
use crate::obs::metrics::Histogram;
use crate::obs::trace::{Event, EventKind};

/// Critical-path accounting for one `(family, sweep)`.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub family: u8,
    pub sweep: u32,
    pub epochs: u32,
    pub tasks: u64,
    /// Serial-equivalent work: Σ task durations.
    pub busy_ns: u64,
    /// Critical path: Σ over epochs of the max per-lane busy time.
    pub crit_ns: u64,
    /// `busy / (workers · crit)` — the paper's load-balance ratio.
    pub eta: f64,
}

/// Busy/steal accounting for one worker lane.
#[derive(Clone, Debug)]
pub struct WorkerRow {
    pub lane: u16,
    pub tasks: u64,
    pub busy_ns: u64,
    /// Tasks this lane executed from the steal queue.
    pub stolen_tasks: u64,
    /// Busy nanos of those stolen tasks.
    pub stolen_ns: u64,
    /// Idle share vs the measured critical path (0 for the busiest
    /// lane of every epoch, by construction).
    pub idle_frac: f64,
}

/// Everything `analyze-trace` reports.
#[derive(Debug)]
pub struct Analysis {
    pub workers: usize,
    pub events: usize,
    pub dropped: u64,
    pub sweeps: Vec<SweepRow>,
    pub worker_rows: Vec<WorkerRow>,
    /// Overall measured-η per family present in the trace.
    pub eta: Vec<(u8, f64)>,
    pub busy_ns: u64,
    pub crit_ns: u64,
    pub steals: u64,
    pub rollbacks: u64,
    pub retries: u64,
    pub io_retries: u64,
    pub io_load_ns: u64,
    pub io_write_ns: u64,
    pub commit_blocking: u64,
    pub commit_runahead: u64,
    pub commit_ns: u64,
    pub checkpoints: u64,
    pub peak_resident_bytes: u64,
    pub task_ns: Histogram,
    pub queue_wait_ns: Histogram,
}

impl Analysis {
    /// Overall measured-η for family 0 (the LDA / BoT-word phase).
    pub fn measured_eta(&self) -> f64 {
        self.eta
            .iter()
            .find(|(f, _)| *f == 0)
            .map(|(_, e)| *e)
            .unwrap_or(1.0)
    }
}

/// Validate the span schema and reduce the event stream.
///
/// Schema: within each `(family, sweep, epoch)` group, task tickets
/// must be exactly `{0..n-1}`, each exactly once, with distinct
/// partitions — i.e. every scheduled task is covered exactly once.
/// Duplicates always fail; gaps fail only when the recorder reported
/// no dropped events (a lossy trace can legitimately have holes).
pub fn analyze(events: &[Event], meta: &TraceMeta) -> Result<Analysis, String> {
    let workers = meta
        .workers
        .max(
            events
                .iter()
                .filter(|e| e.kind == EventKind::Task)
                .map(|e| e.lane as usize + 1)
                .max()
                .unwrap_or(1),
        )
        .max(1);

    // (family, sweep, epoch) -> tickets seen, per-lane busy, partitions.
    #[derive(Default)]
    struct EpochAcc {
        tickets: Vec<u32>,
        partitions: Vec<u64>,
        lane_busy: BTreeMap<u16, u64>,
    }
    let mut groups: BTreeMap<(u8, u32, u32), EpochAcc> = BTreeMap::new();
    let mut worker_rows: BTreeMap<u16, WorkerRow> = BTreeMap::new();
    let task_ns = Histogram::new();
    let queue_wait_ns = Histogram::new();
    let mut an = Analysis {
        workers,
        events: events.len(),
        dropped: meta.dropped,
        sweeps: Vec::new(),
        worker_rows: Vec::new(),
        eta: Vec::new(),
        busy_ns: 0,
        crit_ns: 0,
        steals: 0,
        rollbacks: 0,
        retries: 0,
        io_retries: 0,
        io_load_ns: 0,
        io_write_ns: 0,
        commit_blocking: 0,
        commit_runahead: 0,
        commit_ns: 0,
        checkpoints: 0,
        peak_resident_bytes: 0,
        task_ns: Histogram::new(),
        queue_wait_ns: Histogram::new(),
    };

    for ev in events {
        match ev.kind {
            EventKind::Task => {
                if (ev.lane as usize) >= workers {
                    return Err(format!(
                        "task span on non-worker lane {} (workers={})",
                        ev.lane, workers
                    ));
                }
                let g = groups.entry((ev.family, ev.sweep, ev.epoch)).or_default();
                g.tickets.push(ev.ticket);
                g.partitions.push(ev.partition);
                *g.lane_busy.entry(ev.lane).or_default() += ev.dur_ns;
                let w = worker_rows.entry(ev.lane).or_insert(WorkerRow {
                    lane: ev.lane,
                    tasks: 0,
                    busy_ns: 0,
                    stolen_tasks: 0,
                    stolen_ns: 0,
                    idle_frac: 0.0,
                });
                w.tasks += 1;
                w.busy_ns += ev.dur_ns;
                task_ns.observe(ev.dur_ns);
            }
            EventKind::Steal => {
                an.steals += 1;
                let w = worker_rows.entry(ev.lane).or_insert(WorkerRow {
                    lane: ev.lane,
                    tasks: 0,
                    busy_ns: 0,
                    stolen_tasks: 0,
                    stolen_ns: 0,
                    idle_frac: 0.0,
                });
                w.stolen_tasks += 1;
                w.stolen_ns += ev.arg;
            }
            EventKind::QueueWait => queue_wait_ns.observe(ev.dur_ns),
            EventKind::Rollback => an.rollbacks += 1,
            EventKind::Retry => an.retries += 1,
            EventKind::IoRetry => an.io_retries += ev.arg,
            EventKind::IoLoad => an.io_load_ns += ev.dur_ns,
            EventKind::IoWrite => an.io_write_ns += ev.dur_ns,
            EventKind::Commit => {
                an.commit_ns += ev.dur_ns;
                if ev.arg == 0 {
                    an.commit_blocking += 1;
                } else {
                    an.commit_runahead += 1;
                }
            }
            EventKind::Checkpoint => an.checkpoints += 1,
            EventKind::ResidentBytes => {
                an.peak_resident_bytes = an.peak_resident_bytes.max(ev.arg)
            }
            _ => {}
        }
    }

    // Schema validation + per-sweep critical path.
    let lossless = meta.dropped == 0;
    let mut sweep_acc: BTreeMap<(u8, u32), SweepRow> = BTreeMap::new();
    for ((family, sweep, epoch), g) in &groups {
        let mut tickets = g.tickets.clone();
        tickets.sort_unstable();
        if tickets.windows(2).any(|w| w[0] == w[1]) {
            return Err(format!(
                "duplicate task ticket in family {family} sweep {sweep} epoch {epoch}"
            ));
        }
        let mut parts = g.partitions.clone();
        parts.sort_unstable();
        if parts.windows(2).any(|w| w[0] == w[1]) {
            return Err(format!(
                "duplicate partition in family {family} sweep {sweep} epoch {epoch}"
            ));
        }
        let contiguous = tickets
            .iter()
            .enumerate()
            .all(|(i, &t)| t == i as u32);
        if lossless && !contiguous {
            return Err(format!(
                "ticket gap in family {family} sweep {sweep} epoch {epoch}: \
                 expected 0..{}, got {:?}",
                tickets.len(),
                &tickets[..tickets.len().min(8)]
            ));
        }
        let epoch_busy: u64 = g.lane_busy.values().sum();
        let epoch_crit: u64 = g.lane_busy.values().copied().max().unwrap_or(0);
        let row = sweep_acc.entry((*family, *sweep)).or_insert(SweepRow {
            family: *family,
            sweep: *sweep,
            epochs: 0,
            tasks: 0,
            busy_ns: 0,
            crit_ns: 0,
            eta: 1.0,
        });
        row.epochs += 1;
        row.tasks += g.tickets.len() as u64;
        row.busy_ns += epoch_busy;
        row.crit_ns += epoch_crit;
    }

    let mut fam_busy: BTreeMap<u8, (u64, u64)> = BTreeMap::new();
    for row in sweep_acc.values_mut() {
        if row.crit_ns > 0 {
            row.eta = row.busy_ns as f64 / (workers as f64 * row.crit_ns as f64);
        }
        let f = fam_busy.entry(row.family).or_default();
        f.0 += row.busy_ns;
        f.1 += row.crit_ns;
        an.busy_ns += row.busy_ns;
        an.crit_ns += row.crit_ns;
    }
    an.eta = fam_busy
        .into_iter()
        .map(|(f, (busy, crit))| {
            let eta = if crit == 0 {
                1.0
            } else {
                busy as f64 / (workers as f64 * crit as f64)
            };
            (f, eta)
        })
        .collect();
    an.sweeps = sweep_acc.into_values().collect();

    // Idle fraction: 1 - busy / crit-path wallclock available to lanes.
    let crit_total = an.crit_ns.max(1);
    for w in worker_rows.values_mut() {
        w.idle_frac = 1.0 - (w.busy_ns as f64 / crit_total as f64).min(1.0);
    }
    an.worker_rows = worker_rows.into_values().collect();
    an.task_ns = task_ns;
    an.queue_wait_ns = queue_wait_ns;
    Ok(an)
}

/// Merge per-node traces from one distributed run into a single
/// timeline with node-prefixed lane bands.
///
/// Lane remapping: file `i`'s worker lanes `0..workers_i` move to a
/// contiguous band starting at `Σ_{j<i} workers_j`; every file's
/// coordinator lane folds onto the merged coordinator lane (total
/// workers) and its IO lane onto the merged IO lane (total + 1).
///
/// Task spans are deduplicated across files by `(family, sweep, epoch,
/// ticket)`, keeping the **first** occurrence in argument order: in a
/// distributed run the coordinator's trace carries the authoritative
/// span for every ticket (on the owning node's lane), while each
/// worker's own trace repeats its tickets on its local lane 0 — list
/// the coordinator's file first and worker files add only their
/// non-task events plus any tickets the coordinator never saw
/// (speculation losers, tasks cut off by a crash). Without dedup the
/// merged trace would double-count busy time and fail the
/// exactly-once schema check in [`analyze`].
///
/// Timestamps are left untouched: each recorder has its own time base,
/// and the analyzer only aggregates durations within lanes. The merged
/// label joins the inputs' labels with `" + "`.
pub fn merge_traces(traces: &[(Vec<Event>, TraceMeta)]) -> (Vec<Event>, TraceMeta) {
    let total: usize = traces.iter().map(|(_, m)| m.workers.max(1)).sum();
    let coord = total as u16;
    let io = coord + 1;
    let mut seen = std::collections::BTreeSet::new();
    let mut events = Vec::with_capacity(traces.iter().map(|(e, _)| e.len()).sum());
    let mut dropped = 0u64;
    let mut labels: Vec<&str> = Vec::new();
    let mut base = 0u16;
    for (file_events, meta) in traces {
        let workers = meta.workers.max(1) as u16;
        dropped += meta.dropped;
        if !meta.label.is_empty() {
            labels.push(&meta.label);
        }
        for ev in file_events {
            if ev.kind == EventKind::Task
                && !seen.insert((ev.family, ev.sweep, ev.epoch, ev.ticket))
            {
                continue;
            }
            let mut ev = *ev;
            ev.lane = if ev.lane < workers {
                base + ev.lane
            } else if ev.lane == workers {
                coord
            } else {
                io
            };
            events.push(ev);
        }
        base += workers;
    }
    let meta = TraceMeta {
        workers: total,
        dropped,
        label: labels.join(" + "),
    };
    (events, meta)
}

fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

/// Human-readable report for the CLI.
pub fn render(an: &Analysis) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "trace: {} events, {} workers, {} dropped",
        an.events, an.workers, an.dropped
    );
    for (f, eta) in &an.eta {
        let name = if *f == 0 { "word" } else { "stamp" };
        let _ = writeln!(s, "measured_eta[{name}] = {eta:.4}");
    }
    let _ = writeln!(
        s,
        "critical path: busy {} / crit {} across {} sweep-rows",
        fmt_ns(an.busy_ns),
        fmt_ns(an.crit_ns),
        an.sweeps.len()
    );
    if an.task_ns.count() > 0 {
        let _ = writeln!(
            s,
            "task span: n={} p50={} p95={} p99={} max={}",
            an.task_ns.count(),
            fmt_ns(an.task_ns.p50()),
            fmt_ns(an.task_ns.p95()),
            fmt_ns(an.task_ns.p99()),
            fmt_ns(an.task_ns.max()),
        );
    }
    if an.queue_wait_ns.count() > 0 {
        let _ = writeln!(
            s,
            "queue wait: n={} p50={} p99={}",
            an.queue_wait_ns.count(),
            fmt_ns(an.queue_wait_ns.p50()),
            fmt_ns(an.queue_wait_ns.p99()),
        );
    }
    let _ = writeln!(s, "workers (busy | idle-gap | stolen):");
    for w in &an.worker_rows {
        let _ = writeln!(
            s,
            "  lane {:>2}: {:>10} busy  {:>5.1}% idle  {} tasks  {} stolen ({})",
            w.lane,
            fmt_ns(w.busy_ns),
            100.0 * w.idle_frac,
            w.tasks,
            w.stolen_tasks,
            fmt_ns(w.stolen_ns),
        );
    }
    if an.steals > 0 {
        let stolen_ns: u64 = an.worker_rows.iter().map(|w| w.stolen_ns).sum();
        let _ = writeln!(
            s,
            "steal effectiveness: {} steals moved {} ({:.2}% of busy)",
            an.steals,
            fmt_ns(stolen_ns),
            100.0 * stolen_ns as f64 / an.busy_ns.max(1) as f64
        );
    }
    if an.commit_blocking + an.commit_runahead > 0 {
        let _ = writeln!(
            s,
            "ticketed commits: {} run-ahead, {} blocking, {} fold time",
            an.commit_runahead,
            an.commit_blocking,
            fmt_ns(an.commit_ns)
        );
    }
    if an.io_load_ns + an.io_write_ns > 0 || an.io_retries > 0 {
        let _ = writeln!(
            s,
            "spill io: load {} write {} retries {}",
            fmt_ns(an.io_load_ns),
            fmt_ns(an.io_write_ns),
            an.io_retries
        );
    }
    if an.rollbacks + an.retries > 0 {
        let _ = writeln!(s, "faults: {} rollbacks, {} retries", an.rollbacks, an.retries);
    }
    if an.checkpoints > 0 {
        let _ = writeln!(s, "checkpoints: {}", an.checkpoints);
    }
    if an.peak_resident_bytes > 0 {
        let _ = writeln!(
            s,
            "peak resident+inflight: {:.1} MiB",
            an.peak_resident_bytes as f64 / (1 << 20) as f64
        );
    }
    let show = an.sweeps.len().min(12);
    let _ = writeln!(s, "per-sweep critical path (first {show}):");
    for row in an.sweeps.iter().take(show) {
        let name = if row.family == 0 { "word" } else { "stamp" };
        let _ = writeln!(
            s,
            "  {name} sweep {:>3}: {:>2} epochs {:>4} tasks busy {:>10} crit {:>10} eta {:.4}",
            row.sweep,
            row.epochs,
            row.tasks,
            fmt_ns(row.busy_ns),
            fmt_ns(row.crit_ns),
            row.eta
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(lane: u16, sweep: u32, epoch: u32, ticket: u32, part: u64, dur: u64) -> Event {
        Event {
            lane,
            sweep,
            epoch,
            ticket,
            partition: part,
            dur_ns: dur,
            ..Event::of(EventKind::Task)
        }
    }

    #[test]
    fn eta_matches_hand_computation() {
        // 2 workers, 1 sweep, 2 epochs; epoch 0: lanes busy 100/50,
        // epoch 1: 80/80. busy=310, crit=100+80=180, eta=310/(2*180).
        let evs = vec![
            task(0, 0, 0, 0, 0, 100),
            task(1, 0, 0, 1, 3, 50),
            task(0, 0, 1, 0, 1, 80),
            task(1, 0, 1, 1, 2, 80),
        ];
        let meta = TraceMeta { workers: 2, ..Default::default() };
        let an = analyze(&evs, &meta).unwrap();
        let want = 310.0 / (2.0 * 180.0);
        assert!((an.measured_eta() - want).abs() < 1e-12);
        assert_eq!(an.sweeps.len(), 1);
        assert_eq!(an.sweeps[0].epochs, 2);
        assert_eq!(an.sweeps[0].tasks, 4);
        assert_eq!(an.busy_ns, 310);
        assert_eq!(an.crit_ns, 180);
        // Lane 1 idle: busy 130 of 180 available.
        let w1 = an.worker_rows.iter().find(|w| w.lane == 1).unwrap();
        assert!((w1.idle_frac - (1.0 - 130.0 / 180.0)).abs() < 1e-12);
        assert!(!render(&an).is_empty());
    }

    #[test]
    fn schema_rejects_duplicate_ticket() {
        let evs = vec![task(0, 0, 0, 0, 0, 10), task(1, 0, 0, 0, 1, 10)];
        let meta = TraceMeta { workers: 2, ..Default::default() };
        let err = analyze(&evs, &meta).unwrap_err();
        assert!(err.contains("duplicate task ticket"), "{err}");
    }

    #[test]
    fn schema_rejects_ticket_gap_when_lossless() {
        let evs = vec![task(0, 0, 0, 0, 0, 10), task(1, 0, 0, 2, 1, 10)];
        let mut meta = TraceMeta { workers: 2, ..Default::default() };
        assert!(analyze(&evs, &meta).unwrap_err().contains("ticket gap"));
        // With recorded drops, gaps are tolerated.
        meta.dropped = 5;
        assert!(analyze(&evs, &meta).is_ok());
    }

    #[test]
    fn schema_rejects_duplicate_partition() {
        let evs = vec![task(0, 0, 0, 0, 7, 10), task(1, 0, 0, 1, 7, 10)];
        let meta = TraceMeta { workers: 2, ..Default::default() };
        assert!(analyze(&evs, &meta).unwrap_err().contains("duplicate partition"));
    }

    #[test]
    fn counts_instants_and_commits() {
        let mut evs = vec![task(0, 0, 0, 0, 0, 10)];
        evs.push(Event { arg: 3, ..Event::of(EventKind::Steal) });
        evs.push(Event { ..Event::of(EventKind::Rollback) });
        evs.push(Event { arg: 1, ..Event::of(EventKind::Retry) });
        evs.push(Event { arg: 4, ..Event::of(EventKind::IoRetry) });
        evs.push(Event { dur_ns: 9, arg: 0, ..Event::of(EventKind::Commit) });
        evs.push(Event { dur_ns: 2, arg: 3, ..Event::of(EventKind::Commit) });
        evs.push(Event { arg: 1 << 21, ..Event::of(EventKind::ResidentBytes) });
        let meta = TraceMeta { workers: 1, ..Default::default() };
        let an = analyze(&evs, &meta).unwrap();
        assert_eq!(an.steals, 1);
        assert_eq!(an.rollbacks, 1);
        assert_eq!(an.retries, 1);
        assert_eq!(an.io_retries, 4);
        assert_eq!(an.commit_blocking, 1);
        assert_eq!(an.commit_runahead, 1);
        assert_eq!(an.commit_ns, 11);
        assert_eq!(an.peak_resident_bytes, 1 << 21);
    }

    #[test]
    fn merge_remaps_lanes_into_node_bands() {
        // Coordinator file: 2 worker lanes + coordinator(2) + io(3).
        let coord = vec![
            task(0, 0, 0, 0, 0, 100),
            task(1, 0, 0, 1, 3, 50),
            Event { lane: 2, ..Event::of(EventKind::Sweep) },
            Event { lane: 3, ..Event::of(EventKind::IoLoad) },
        ];
        let cmeta = TraceMeta { workers: 2, label: "coord".into(), ..Default::default() };
        // One worker file: 1 worker lane + coordinator(1) + io(2).
        let wk = vec![
            task(0, 0, 0, 0, 0, 100), // duplicate of coordinator's ticket 0
            Event { lane: 1, ..Event::of(EventKind::Barrier) },
        ];
        let wmeta = TraceMeta { workers: 1, dropped: 2, label: "node-0".into(), ..Default::default() };
        let (evs, meta) = merge_traces(&[(coord, cmeta), (wk, wmeta)]);
        assert_eq!(meta.workers, 3);
        assert_eq!(meta.dropped, 2);
        assert_eq!(meta.label, "coord + node-0");
        // The duplicate task span was dropped; first file won.
        let tasks: Vec<&Event> = evs.iter().filter(|e| e.kind == EventKind::Task).collect();
        assert_eq!(tasks.len(), 2);
        assert!(tasks.iter().all(|e| e.lane < 2), "coordinator lanes win");
        // File 0's coordinator lane folded onto merged lane 3, io onto 4;
        // file 1's coordinator lane likewise.
        assert!(evs.iter().any(|e| e.kind == EventKind::Sweep && e.lane == 3));
        assert!(evs.iter().any(|e| e.kind == EventKind::IoLoad && e.lane == 4));
        assert!(evs.iter().any(|e| e.kind == EventKind::Barrier && e.lane == 3));
        // The merged trace passes the analyzer's exactly-once schema.
        analyze(&evs, &meta).unwrap();
    }

    #[test]
    fn merge_keeps_tickets_only_one_file_saw() {
        // Worker file contributes ticket 1, which the coordinator's
        // trace lost to a crash; bands shift it onto lane 2.
        let coord = vec![task(0, 0, 0, 0, 0, 10)];
        let cmeta = TraceMeta { workers: 2, dropped: 1, ..Default::default() };
        let wk = vec![task(0, 0, 0, 1, 5, 20)];
        let wmeta = TraceMeta { workers: 1, ..Default::default() };
        let (evs, meta) = merge_traces(&[(coord, cmeta), (wk, wmeta)]);
        let tasks: Vec<&Event> = evs.iter().filter(|e| e.kind == EventKind::Task).collect();
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[1].lane, 2, "worker band starts after coordinator's lanes");
        let an = analyze(&evs, &meta).unwrap();
        assert_eq!(an.busy_ns, 30);
    }
}
