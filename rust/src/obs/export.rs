//! Trace export/import: Chrome-trace (Perfetto-loadable) JSON and
//! newline-delimited JSONL, plus a reader that sniffs either format so
//! `pplda analyze-trace` consumes both.
//!
//! The Chrome form renders spans as complete events (`ph:"X"`, µs
//! timestamps) with every raw field preserved in `args` — export is
//! lossless and `read_events` reconstructs the exact [`Event`] stream.

use std::io::Write as _;
use std::path::Path;

use crate::obs::trace::{Event, EventKind};
use crate::util::json::Json;

/// Run-level context carried alongside the event stream.
#[derive(Clone, Debug, Default)]
pub struct TraceMeta {
    /// Worker count (lane layout: `0..workers` workers, then
    /// coordinator, then IO).
    pub workers: usize,
    /// Events lost to full rings during recording.
    pub dropped: u64,
    /// Free-form run label (e.g. the CLI invocation).
    pub label: String,
}

fn lane_name(lane: u16, workers: usize) -> String {
    let lane = lane as usize;
    if lane < workers {
        format!("worker {lane}")
    } else if lane == workers {
        "coordinator".to_string()
    } else {
        "io".to_string()
    }
}

fn family_name(family: u8) -> &'static str {
    if family == 0 {
        "word"
    } else {
        "stamp"
    }
}

/// The raw-field args object shared by both formats — the lossless
/// encoding `read_events` parses back.
fn args_json(ev: &Event) -> Json {
    let mut a = Json::obj();
    a.set("kind", ev.kind.name())
        .set("family", ev.family as u64)
        .set("lane", ev.lane as u64)
        .set("sweep", ev.sweep as u64)
        .set("epoch", ev.epoch as u64)
        .set("ticket", ev.ticket as u64)
        .set("partition", ev.partition)
        .set("t0_ns", ev.t0_ns)
        .set("dur_ns", ev.dur_ns)
        .set("arg", ev.arg);
    a
}

fn event_from_args(j: &Json) -> Result<Event, String> {
    let kind = j
        .get("kind")
        .and_then(Json::as_str)
        .and_then(EventKind::parse)
        .ok_or("missing/unknown event kind")?;
    let num = |key: &str| j.get(key).and_then(Json::as_u64).unwrap_or(0);
    Ok(Event {
        kind,
        family: num("family") as u8,
        lane: num("lane") as u16,
        sweep: num("sweep") as u32,
        epoch: num("epoch") as u32,
        ticket: num("ticket") as u32,
        partition: num("partition"),
        t0_ns: num("t0_ns"),
        dur_ns: num("dur_ns"),
        arg: num("arg"),
    })
}

/// Build the Chrome-trace document (object form: `traceEvents` +
/// `otherData`), loadable by Perfetto / `chrome://tracing`.
pub fn chrome_trace(events: &[Event], meta: &TraceMeta) -> Json {
    let mut trace_events = Vec::new();
    // Thread-name metadata rows so Perfetto labels the lanes.
    let max_lane = events.iter().map(|e| e.lane).max().unwrap_or(0);
    let lanes = (meta.workers + 2).max(max_lane as usize + 1);
    for lane in 0..lanes as u16 {
        let mut m = Json::obj();
        let mut args = Json::obj();
        args.set("name", lane_name(lane, meta.workers));
        m.set("name", "thread_name")
            .set("ph", "M")
            .set("pid", 0u64)
            .set("tid", lane as u64)
            .set("args", args);
        trace_events.push(m);
    }
    for ev in events {
        let mut e = Json::obj();
        e.set("name", ev.kind.name())
            .set("cat", family_name(ev.family))
            .set("pid", 0u64)
            .set("tid", ev.lane as u64)
            .set("ts", ev.t0_ns as f64 / 1e3)
            .set("args", args_json(ev));
        if ev.kind == EventKind::ResidentBytes {
            e.set("ph", "C");
        } else if ev.kind.is_span() {
            e.set("ph", "X").set("dur", ev.dur_ns as f64 / 1e3);
        } else {
            e.set("ph", "i").set("s", "t");
        }
        trace_events.push(e);
    }
    let mut other = Json::obj();
    other
        .set("tool", "pplda")
        .set("workers", meta.workers)
        .set("dropped", meta.dropped)
        .set("label", meta.label.as_str());
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(trace_events))
        .set("displayTimeUnit", "ms")
        .set("otherData", other);
    doc
}

/// JSONL form: a leading meta record, then one event object per line.
pub fn jsonl(events: &[Event], meta: &TraceMeta) -> String {
    let mut out = String::new();
    let mut m = Json::obj();
    m.set("meta", true)
        .set("tool", "pplda")
        .set("workers", meta.workers)
        .set("dropped", meta.dropped)
        .set("label", meta.label.as_str());
    out.push_str(&m.to_string());
    out.push('\n');
    for ev in events {
        out.push_str(&args_json(ev).to_string());
        out.push('\n');
    }
    out
}

/// Write `events` to `path`; `.jsonl` extension selects JSONL,
/// anything else gets Chrome-trace JSON.
pub fn write_trace(path: &Path, events: &[Event], meta: &TraceMeta) -> std::io::Result<()> {
    let text = if path.extension().is_some_and(|e| e == "jsonl") {
        jsonl(events, meta)
    } else {
        chrome_trace(events, meta).to_string()
    };
    let mut f = std::fs::File::create(path)?;
    f.write_all(text.as_bytes())?;
    f.flush()
}

/// Parse a trace previously written by [`write_trace`] (either
/// format, sniffed from content).
pub fn parse_trace(text: &str) -> Result<(Vec<Event>, TraceMeta), String> {
    let trimmed = text.trim_start();
    if trimmed.starts_with('{') && !trimmed.lines().next().unwrap_or("").contains("\"meta\"") {
        parse_chrome(text)
    } else {
        parse_jsonl(text)
    }
}

/// Read and parse a trace file.
pub fn read_trace(path: &Path) -> Result<(Vec<Event>, TraceMeta), String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_trace(&text)
}

fn parse_chrome(text: &str) -> Result<(Vec<Event>, TraceMeta), String> {
    let doc = Json::parse(text)?;
    let rows = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    let mut events = Vec::new();
    for row in rows {
        if row.get("ph").and_then(Json::as_str) == Some("M") {
            continue;
        }
        let args = row.get("args").ok_or("trace event without args")?;
        events.push(event_from_args(args)?);
    }
    let other = doc.get("otherData");
    let meta = TraceMeta {
        workers: other
            .and_then(|o| o.get("workers"))
            .and_then(Json::as_u64)
            .unwrap_or(0) as usize,
        dropped: other
            .and_then(|o| o.get("dropped"))
            .and_then(Json::as_u64)
            .unwrap_or(0),
        label: other
            .and_then(|o| o.get("label"))
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
    };
    Ok((events, meta))
}

fn parse_jsonl(text: &str) -> Result<(Vec<Event>, TraceMeta), String> {
    let mut events = Vec::new();
    let mut meta = TraceMeta::default();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        if j.get("meta").is_some() {
            meta.workers = j.get("workers").and_then(Json::as_u64).unwrap_or(0) as usize;
            meta.dropped = j.get("dropped").and_then(Json::as_u64).unwrap_or(0);
            meta.label = j
                .get("label")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            continue;
        }
        events.push(event_from_args(&j).map_err(|e| format!("line {}: {e}", i + 1))?);
    }
    Ok((events, meta))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<Event> {
        vec![
            Event {
                lane: 0,
                sweep: 1,
                epoch: 2,
                ticket: 3,
                partition: 42,
                t0_ns: 1_000,
                dur_ns: 5_000,
                ..Event::of(EventKind::Task)
            },
            Event {
                family: 1,
                lane: 4,
                sweep: 1,
                t0_ns: 7_000,
                arg: 2,
                ..Event::of(EventKind::Rollback)
            },
            Event {
                lane: 5,
                t0_ns: 9_000,
                arg: 123_456,
                ..Event::of(EventKind::ResidentBytes)
            },
        ]
    }

    #[test]
    fn chrome_round_trip_is_lossless() {
        let evs = sample_events();
        let meta = TraceMeta { workers: 4, dropped: 1, label: "t".into() };
        let text = chrome_trace(&evs, &meta).to_string();
        let (back, m) = parse_trace(&text).unwrap();
        assert_eq!(back, evs);
        assert_eq!(m.workers, 4);
        assert_eq!(m.dropped, 1);
        assert_eq!(m.label, "t");
    }

    #[test]
    fn jsonl_round_trip_is_lossless() {
        let evs = sample_events();
        let meta = TraceMeta { workers: 4, dropped: 0, label: "run".into() };
        let text = jsonl(&evs, &meta);
        assert_eq!(text.lines().count(), evs.len() + 1);
        let (back, m) = parse_trace(&text).unwrap();
        assert_eq!(back, evs);
        assert_eq!(m.workers, 4);
        assert_eq!(m.label, "run");
    }

    #[test]
    fn chrome_doc_has_perfetto_shape() {
        let evs = sample_events();
        let meta = TraceMeta { workers: 4, ..Default::default() };
        let doc = chrome_trace(&evs, &meta);
        let rows = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 6 thread-name metadata rows (4 workers + coord + io) + events.
        let metas: Vec<_> = rows
            .iter()
            .filter(|r| r.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(metas.len(), 6);
        let span = rows
            .iter()
            .find(|r| r.get("ph").and_then(Json::as_str) == Some("X"))
            .expect("task span present");
        assert_eq!(span.get("name").and_then(Json::as_str), Some("task"));
        assert_eq!(span.get("ts").and_then(Json::as_f64), Some(1.0));
        assert_eq!(span.get("dur").and_then(Json::as_f64), Some(5.0));
        assert!(rows
            .iter()
            .any(|r| r.get("ph").and_then(Json::as_str) == Some("C")));
        assert!(rows
            .iter()
            .any(|r| r.get("ph").and_then(Json::as_str) == Some("i")));
    }

    #[test]
    fn file_round_trip_by_extension() {
        let dir = std::env::temp_dir().join(format!("pplda_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let evs = sample_events();
        let meta = TraceMeta { workers: 2, ..Default::default() };
        for name in ["t.json", "t.jsonl"] {
            let p = dir.join(name);
            write_trace(&p, &evs, &meta).unwrap();
            let (back, m) = read_trace(&p).unwrap();
            assert_eq!(back, evs, "{name}");
            assert_eq!(m.workers, 2, "{name}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
