//! In-tree micro/macro-benchmark harness (offline replacement for
//! `criterion`). Benches are plain binaries with `harness = false`; each
//! builds a [`Bench`] runner, registers closures, and prints/records an
//! aligned results table.
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum measurement time are reached; reports
//! mean/median/p95 per iteration plus derived throughput when the caller
//! provides an items-per-iteration hint.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;
use crate::util::tsv::Table;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 100,
            min_time: Duration::from_millis(300),
        }
    }
}

impl BenchConfig {
    /// Heavier workloads (full partitioner runs, training sweeps) need
    /// fewer iterations.
    pub fn heavy() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            min_time: Duration::from_millis(200),
        }
    }

    /// Honour PPLDA_BENCH_FAST=1 so the full `cargo bench` suite stays
    /// tractable on small CI boxes.
    pub fn from_env(base: Self) -> Self {
        if std::env::var("PPLDA_BENCH_FAST").as_deref() == Ok("1") {
            Self {
                warmup_iters: 0,
                min_iters: 1,
                max_iters: 2,
                min_time: Duration::ZERO,
            }
        } else {
            base
        }
    }
}

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub per_iter: Summary,
    /// Items (e.g. tokens) processed per iteration, if provided.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.per_iter.mean)
    }
}

pub struct Bench {
    config: BenchConfig,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(config: BenchConfig) -> Self {
        Self {
            config: BenchConfig::from_env(config),
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs one full iteration per call.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &Measurement {
        self.run_with_items(name, None, move || {
            f();
        })
    }

    /// Time `f` with a per-iteration item count for throughput reporting.
    pub fn run_with_items(
        &mut self,
        name: &str,
        items_per_iter: Option<f64>,
        mut f: impl FnMut(),
    ) -> &Measurement {
        for _ in 0..self.config.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        while samples.len() < self.config.min_iters
            || (started.elapsed() < self.config.min_time
                && samples.len() < self.config.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            per_iter: Summary::of(&samples),
            items_per_iter,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Aligned results table; includes throughput column when any
    /// measurement carries an item count.
    pub fn table(&self) -> Table {
        let with_tp = self.results.iter().any(|m| m.items_per_iter.is_some());
        let mut header = vec!["name", "iters", "mean_s", "median_s", "p95_s"];
        if with_tp {
            header.push("items/s");
        }
        let mut t = Table::new(header);
        for m in &self.results {
            let mut row = vec![
                m.name.clone(),
                m.iters.to_string(),
                format!("{:.6}", m.per_iter.mean),
                format!("{:.6}", m.per_iter.median),
                format!("{:.6}", m.per_iter.p95),
            ];
            if with_tp {
                row.push(
                    m.throughput()
                        .map(crate::util::human_rate)
                        .unwrap_or_else(|| "-".to_string()),
                );
            }
            t.row(row);
        }
        t
    }
}

/// Prevent the optimizer from discarding a computed value (stable-rust
/// friendly black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::new(BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 5,
            min_time: Duration::ZERO,
        });
        let m = b.run_with_items("spin", Some(1000.0), || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(m.iters >= 3);
        assert!(m.per_iter.mean > 0.0);
        assert!(m.throughput().unwrap() > 0.0);
        let table = b.table();
        assert_eq!(table.num_rows(), 1);
        assert!(table.to_aligned().contains("spin"));
    }

    #[test]
    fn respects_max_iters() {
        let mut b = Bench::new(BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 3,
            min_time: Duration::from_secs(10),
        });
        let m = b.run("fast", || {});
        assert!(m.iters <= 3);
    }
}
