//! # pplda — Partitioned Parallel LDA
//!
//! Reproduction of **Tran & Takasu, "Partitioning Algorithms for Improving
//! Efficiency of Topic Modeling Parallelization" (PACRIM 2015)** as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The paper improves the data-partitioning parallelization of collapsed
//! Gibbs sampling for LDA (Yan et al., NIPS 2009): the document–word
//! matrix is split `P×P`; partitions along each wrapped diagonal are
//! read–write non-conflicting and sampled by `P` workers in parallel, with
//! a barrier between the `P` diagonal *epochs* of every Gibbs sweep. The
//! slowest partition of each epoch gates the sweep, so the quality of the
//! partitioning — measured by the load-balancing ratio `η = C_opt / C` —
//! directly sets the speedup (`≈ η·P`).
//!
//! This crate implements:
//!
//! * [`partition`] — the paper's contribution: deterministic algorithms
//!   **A1**/**A2**, the stratified randomized algorithm **A3**, and the
//!   Yan-et-al random-shuffle **baseline**, plus the `η` metric.
//! * [`gibbs`] — collapsed Gibbs sampling for LDA (serial reference and
//!   the per-partition kernel used by the parallel engine).
//! * [`kernel`] — pluggable per-partition sampling kernels behind the
//!   `Kernel` trait: the dense O(K) scan, the SparseLDA s/r/q bucket
//!   decomposition, and the alias-table sampler with MH staleness
//!   correction (see `docs/kernels.md`).
//! * [`scheduler`] — the diagonal-epoch plan, a worker pool, the
//!   epoch-cost model, and the cost-aware adaptive layer (measured
//!   per-partition cost estimators, sweep-to-sweep re-packing, and a
//!   work-stealing execution mode — see `docs/scheduling.md`).
//! * [`bot`] — Bag of Timestamps (Masada et al. 2009): the LDA extension
//!   with a second document–timestamp matrix, parallelized with the same
//!   partitioning machinery (paper §IV-C).
//! * [`corpus`] — bag-of-words substrate: CSR storage, UCI loader, and
//!   synthetic generators whose marginals match NIPS / NYTimes / MAS
//!   (Table I) so the experiments run without the original datasets.
//! * [`runtime`] — PJRT executor loading the AOT-compiled JAX/Pallas
//!   kernels (HLO text) for the offloaded sampler / perplexity hot path.
//!   Compiled only with the `xla` cargo feature (needs the external `xla`
//!   bindings crate); the default build is dependency-free.
//! * [`coordinator`] — the training drivers tying everything together.
//! * [`dist`] — the fault-tolerant distributed execution layer: a
//!   coordinator/worker multi-process protocol (JSON-lines control
//!   plane, CRC-framed binary task/delta plane) with heartbeats,
//!   liveness timeouts, deterministic shard reassignment on worker
//!   death, and speculative re-execution of stragglers — bit-identical
//!   to single-process training (see `docs/distributed.md`).
//! * [`serve`] — the production-facing inference half: crash-safe
//!   `PPSNAP1` model snapshots with atomic hot-reload, an exact O(1)
//!   per-token fold-in engine, and a batched query server with bounded
//!   admission, deadlines, graceful degradation, panic containment, and
//!   graceful drain (see `docs/serving.md`).
//! * [`obs`] — structured tracing (per-task spans into lock-free ring
//!   buffers, Perfetto/JSONL export, `analyze-trace`) and the metrics
//!   registry the phase reports are views over (see
//!   `docs/observability.md`).
//! * [`util`], [`testing`], [`bench`] — in-tree substrates (PRNG, CLI,
//!   stats, JSON/TSV, property-testing, bench harness) required by the
//!   offline build environment.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pplda::corpus::synthetic::{self, Profile};
//! use pplda::partition::{self, Algorithm};
//! use pplda::coordinator::{TrainConfig, train_lda};
//!
//! let corpus = synthetic::generate(&Profile::nips_like().scaled(10), 42);
//! let plan = partition::partition(&corpus, 8, Algorithm::A3 { restarts: 20 }, 7);
//! println!("eta = {:.4}", plan.eta);
//! let cfg = TrainConfig { topics: 64, iters: 50, ..Default::default() };
//! let report = train_lda(&corpus, &plan, &cfg);
//! println!("perplexity = {:.2}", report.final_perplexity);
//! ```

pub mod bench;
pub mod bot;
pub mod coordinator;
pub mod corpus;
pub mod dist;
pub mod gibbs;
pub mod kernel;
pub mod obs;
pub mod partition;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod testing;
pub mod util;
