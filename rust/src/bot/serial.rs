//! Serial (nonparallel) BoT trainer — the "Nonparallel" column of the
//! paper's Table IV.

use crate::corpus::timestamps::TimestampedCorpus;
use crate::bot::counts::BotCounts;
use crate::gibbs::sampler::{draw, Hyper};
use crate::gibbs::tokens::TokenBlock;
use crate::util::rng::Rng;

/// BoT hyperparameters (paper §V-C: α=0.5, β=0.1, γ=0.1, K=256, L=16).
#[derive(Clone, Copy, Debug)]
pub struct BotHyper {
    pub k: usize,
    pub alpha: f32,
    pub beta: f32,
    pub gamma: f32,
    /// `W·β`.
    pub wbeta: f32,
    /// `S·γ` (S = number of distinct timestamps).
    pub sgamma: f32,
}

impl BotHyper {
    pub fn new(
        k: usize,
        alpha: f32,
        beta: f32,
        gamma: f32,
        num_words: usize,
        num_stamps: usize,
    ) -> Self {
        Self {
            k,
            alpha,
            beta,
            gamma,
            wbeta: beta * num_words as f32,
            sgamma: gamma * num_stamps as f32,
        }
    }

    /// The word-phase parameters as a plain LDA [`Hyper`].
    pub fn word_hyper(&self) -> Hyper {
        Hyper {
            k: self.k,
            alpha: self.alpha,
            beta: self.beta,
            wbeta: self.wbeta,
        }
    }

    /// The timestamp-phase parameters as a plain LDA [`Hyper`] (γ in
    /// place of β, S in place of W).
    pub fn stamp_hyper(&self) -> Hyper {
        Hyper {
            k: self.k,
            alpha: self.alpha,
            beta: self.gamma,
            wbeta: self.sgamma,
        }
    }
}

pub struct SerialBot {
    pub h: BotHyper,
    pub counts: BotCounts,
    pub words: TokenBlock,
    pub stamps: TokenBlock,
    rng: Rng,
    probs: Vec<f32>,
}

impl SerialBot {
    pub fn init(tc: &TimestampedCorpus, h: BotHyper, seed: u64) -> Self {
        let mut rng = Rng::stream(seed, 0xB07);
        let words = TokenBlock::from_corpus(&tc.bow, h.k, &mut rng);
        let stamps = TokenBlock::from_corpus(&tc.dts, h.k, &mut rng);
        let mut counts = BotCounts::zeros(
            tc.bow.num_docs(),
            tc.bow.num_words(),
            tc.num_stamps,
            h.k,
        );
        counts.absorb_words(&words);
        counts.absorb_stamps(&stamps);
        Self {
            h,
            counts,
            words,
            stamps,
            rng,
            probs: Vec::new(),
        }
    }

    /// One full sweep: all word tokens, then all timestamp tokens.
    pub fn sweep(&mut self) {
        let k = self.h.k;
        self.probs.resize(k, 0.0);

        // Word phase.
        for i in 0..self.words.len() {
            let d = self.words.docs[i] as usize;
            let w = self.words.words[i] as usize;
            let old = self.words.z[i] as usize;
            self.counts.doc_topic[d * k + old] -= 1.0;
            self.counts.word_topic[w * k + old] -= 1.0;
            self.counts.topic_words[old] -= 1;
            let mut total = 0.0f32;
            for t in 0..k {
                let p = (self.counts.doc_topic[d * k + t] + self.h.alpha)
                    * (self.counts.word_topic[w * k + t] + self.h.beta)
                    / (self.counts.topic_words[t] as f32 + self.h.wbeta);
                self.probs[t] = p;
                total += p;
            }
            let new = draw(&self.probs, total, &mut self.rng);
            self.counts.doc_topic[d * k + new] += 1.0;
            self.counts.word_topic[w * k + new] += 1.0;
            self.counts.topic_words[new] += 1;
            self.words.z[i] = new as u32;
        }

        // Timestamp phase.
        for i in 0..self.stamps.len() {
            let d = self.stamps.docs[i] as usize;
            let s = self.stamps.words[i] as usize;
            let old = self.stamps.z[i] as usize;
            self.counts.doc_topic[d * k + old] -= 1.0;
            self.counts.stamp_topic[s * k + old] -= 1.0;
            self.counts.topic_stamps[old] -= 1;
            let mut total = 0.0f32;
            for t in 0..k {
                let p = (self.counts.doc_topic[d * k + t] + self.h.alpha)
                    * (self.counts.stamp_topic[s * k + t] + self.h.gamma)
                    / (self.counts.topic_stamps[t] as f32 + self.h.sgamma);
                self.probs[t] = p;
                total += p;
            }
            let new = draw(&self.probs, total, &mut self.rng);
            self.counts.doc_topic[d * k + new] += 1.0;
            self.counts.stamp_topic[s * k + new] += 1.0;
            self.counts.topic_stamps[new] += 1;
            self.stamps.z[i] = new as u32;
        }
    }

    pub fn train(
        &mut self,
        tc: &TimestampedCorpus,
        iters: usize,
        eval_every: usize,
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for it in 1..=iters {
            self.sweep();
            if eval_every > 0 && (it % eval_every == 0 || it == iters) {
                curve.push((it, self.perplexity(tc)));
            }
        }
        curve
    }

    /// Word perplexity under BoT's θ (which includes timestamp mass in
    /// `n_j`) and φ — the Table IV metric.
    pub fn perplexity(&self, tc: &TimestampedCorpus) -> f64 {
        super::perplexity_words(&tc.bow, &self.counts, &self.h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate_timestamped, Profile, TimeProfile};

    fn tiny_tc(seed: u64) -> TimestampedCorpus {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        generate_timestamped(&p, seed)
    }

    #[test]
    fn sweep_conserves_counts() {
        let tc = tiny_tc(51);
        let h = BotHyper::new(4, 0.5, 0.1, 0.1, tc.bow.num_words(), tc.num_stamps);
        let mut bot = SerialBot::init(&tc, h, 1);
        let n = bot.counts.total();
        for _ in 0..3 {
            bot.sweep();
        }
        assert_eq!(bot.counts.total(), n);
        assert!(bot
            .counts
            .check_consistency(&[&bot.words], &[&bot.stamps])
            .is_ok());
    }

    #[test]
    fn training_reduces_perplexity() {
        let tc = tiny_tc(52);
        let h = BotHyper::new(8, 0.5, 0.1, 0.1, tc.bow.num_words(), tc.num_stamps);
        let mut bot = SerialBot::init(&tc, h, 2);
        let p0 = bot.perplexity(&tc);
        bot.train(&tc, 30, 0);
        let p1 = bot.perplexity(&tc);
        assert!(p1 < p0 * 0.9, "{p0} → {p1}");
    }

    #[test]
    fn hyper_views() {
        let h = BotHyper::new(4, 0.5, 0.1, 0.2, 100, 10);
        let wh = h.word_hyper();
        assert_eq!(wh.wbeta, 10.0);
        let sh = h.stamp_hyper();
        assert_eq!(sh.beta, 0.2);
        assert!((sh.wbeta - 2.0).abs() < 1e-6);
    }
}
