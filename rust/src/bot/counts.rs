//! BoT count matrices: shared document–topic counts plus separate word
//! and timestamp emission counts (paper Fig. 2's `C_Theta`, `C_Phi`,
//! `C_Pi`).

use crate::gibbs::tokens::TokenBlock;

#[derive(Clone, Debug)]
pub struct BotCounts {
    pub k: usize,
    pub num_docs: usize,
    pub num_words: usize,
    pub num_stamps: usize,
    /// `n_jk` over words *and* timestamps (shared θ), `[D][K]`.
    pub doc_topic: Vec<f32>,
    /// `n_kw`, word-major `[W][K]` (C_Phi).
    pub word_topic: Vec<f32>,
    /// `n_ks`, stamp-major `[S][K]` (C_Pi).
    pub stamp_topic: Vec<f32>,
    /// `n_k^W` — word tokens per topic.
    pub topic_words: Vec<u32>,
    /// `n_k^TS` — timestamp tokens per topic.
    pub topic_stamps: Vec<u32>,
}

impl BotCounts {
    pub fn zeros(num_docs: usize, num_words: usize, num_stamps: usize, k: usize) -> Self {
        Self {
            k,
            num_docs,
            num_words,
            num_stamps,
            doc_topic: vec![0.0; num_docs * k],
            word_topic: vec![0.0; num_words * k],
            stamp_topic: vec![0.0; num_stamps * k],
            topic_words: vec![0; k],
            topic_stamps: vec![0; k],
        }
    }

    /// Accumulate word-token assignments.
    pub fn absorb_words(&mut self, block: &TokenBlock) {
        for i in 0..block.len() {
            let (d, w, z) = (
                block.docs[i] as usize,
                block.words[i] as usize,
                block.z[i] as usize,
            );
            self.doc_topic[d * self.k + z] += 1.0;
            self.word_topic[w * self.k + z] += 1.0;
            self.topic_words[z] += 1;
        }
    }

    /// Accumulate timestamp-token assignments (`block.words` holds stamp
    /// ids).
    pub fn absorb_stamps(&mut self, block: &TokenBlock) {
        for i in 0..block.len() {
            let (d, s, z) = (
                block.docs[i] as usize,
                block.words[i] as usize,
                block.z[i] as usize,
            );
            self.doc_topic[d * self.k + z] += 1.0;
            self.stamp_topic[s * self.k + z] += 1.0;
            self.topic_stamps[z] += 1;
        }
    }

    #[inline]
    pub fn doc_row(&self, j: usize) -> &[f32] {
        &self.doc_topic[j * self.k..(j + 1) * self.k]
    }

    #[inline]
    pub fn word_row(&self, w: usize) -> &[f32] {
        &self.word_topic[w * self.k..(w + 1) * self.k]
    }

    #[inline]
    pub fn stamp_row(&self, s: usize) -> &[f32] {
        &self.stamp_topic[s * self.k..(s + 1) * self.k]
    }

    /// Total assigned tokens (words + stamps) — conservation invariant.
    pub fn total(&self) -> u64 {
        self.topic_words.iter().map(|&c| c as u64).sum::<u64>()
            + self.topic_stamps.iter().map(|&c| c as u64).sum::<u64>()
    }

    /// Full consistency check against the blocks (test helper).
    pub fn check_consistency(
        &self,
        word_blocks: &[&TokenBlock],
        stamp_blocks: &[&TokenBlock],
    ) -> Result<(), String> {
        let mut expect =
            BotCounts::zeros(self.num_docs, self.num_words, self.num_stamps, self.k);
        for b in word_blocks {
            expect.absorb_words(b);
        }
        for b in stamp_blocks {
            expect.absorb_stamps(b);
        }
        if expect.doc_topic != self.doc_topic {
            return Err("doc_topic mismatch".into());
        }
        if expect.word_topic != self.word_topic {
            return Err("word_topic mismatch".into());
        }
        if expect.stamp_topic != self.stamp_topic {
            return Err("stamp_topic mismatch".into());
        }
        if expect.topic_words != self.topic_words {
            return Err("topic_words mismatch".into());
        }
        if expect.topic_stamps != self.topic_stamps {
            return Err("topic_stamps mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_both_sides_updates_shared_theta() {
        let mut c = BotCounts::zeros(2, 3, 4, 2);
        let words = TokenBlock {
            docs: vec![0, 0],
            words: vec![1, 2],
            z: vec![0, 1],
        };
        let stamps = TokenBlock {
            docs: vec![0, 1],
            words: vec![3, 0],
            z: vec![0, 0],
        };
        c.absorb_words(&words);
        c.absorb_stamps(&stamps);
        // Doc 0: 2 word tokens + 1 stamp token.
        assert_eq!(c.doc_row(0), &[2.0, 1.0]);
        assert_eq!(c.doc_row(1), &[1.0, 0.0]);
        assert_eq!(c.topic_words, vec![1, 1]);
        assert_eq!(c.topic_stamps, vec![2, 0]);
        assert_eq!(c.stamp_row(3), &[1.0, 0.0]);
        assert_eq!(c.total(), 4);
        assert!(c.check_consistency(&[&words], &[&stamps]).is_ok());
    }

    #[test]
    fn consistency_detects_cross_side_corruption() {
        let mut c = BotCounts::zeros(1, 1, 1, 1);
        let words = TokenBlock {
            docs: vec![0],
            words: vec![0],
            z: vec![0],
        };
        c.absorb_words(&words);
        // Corrupt the stamp side only.
        c.topic_stamps[0] += 1;
        assert!(c.check_consistency(&[&words], &[]).is_err());
    }
}
