//! Topic-over-time analysis (the paper's §I contribution 3: "We
//! demonstrate analysis of this dataset using the designed BoT
//! parallelization").
//!
//! From a trained BoT model, `π_{s|k} = (n_ks + γ)/(n_k^TS + Sγ)` gives
//! each topic's distribution over timestamps — "the presence of a topic
//! in the time line" (paper §IV-C). This module extracts per-topic
//! timelines, peak years, and a rising/falling trend classification.

use crate::bot::counts::BotCounts;
use crate::bot::serial::BotHyper;
use crate::util::tsv::Table;

/// One topic's presence over the timeline.
#[derive(Clone, Debug)]
pub struct TopicTimeline {
    pub topic: usize,
    /// `π_{s|k}` over timestamps, normalized.
    pub pi: Vec<f64>,
    /// Timestamp index with maximum presence.
    pub peak: usize,
    /// Linear-regression slope of presence over time (per timestamp
    /// step); > 0 ⇒ rising topic.
    pub slope: f64,
    /// Total timestamp tokens assigned to the topic.
    pub mass: u64,
}

/// Extract `π` timelines for all topics.
pub fn timelines(counts: &BotCounts, h: &BotHyper) -> Vec<TopicTimeline> {
    let k = h.k;
    let s = counts.num_stamps;
    (0..k)
        .map(|t| {
            let nk = counts.topic_stamps[t] as f64;
            let denom = nk + h.sgamma as f64;
            let pi: Vec<f64> = (0..s)
                .map(|st| (counts.stamp_topic[st * k + t] as f64 + h.gamma as f64) / denom)
                .collect();
            let peak = pi
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            TopicTimeline {
                topic: t,
                slope: linear_slope(&pi),
                peak,
                mass: counts.topic_stamps[t] as u64,
                pi,
            }
        })
        .collect()
}

/// Least-squares slope of `y` against `0..n`.
fn linear_slope(y: &[f64]) -> f64 {
    let n = y.len() as f64;
    if y.len() < 2 {
        return 0.0;
    }
    let mean_x = (n - 1.0) / 2.0;
    let mean_y: f64 = y.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &v) in y.iter().enumerate() {
        let dx = i as f64 - mean_x;
        num += dx * (v - mean_y);
        den += dx * dx;
    }
    num / den
}

/// Render the strongest rising and falling topics as a report table.
pub fn trend_table(tls: &[TopicTimeline], first_year: u32, top: usize) -> Table {
    let mut sorted: Vec<&TopicTimeline> = tls.iter().collect();
    sorted.sort_by(|a, b| b.slope.partial_cmp(&a.slope).unwrap());
    let mut t = Table::new(["trend", "topic", "peak_year", "slope", "stamp_tokens"]);
    for tl in sorted.iter().take(top) {
        t.row([
            "rising".to_string(),
            tl.topic.to_string(),
            (first_year + tl.peak as u32).to_string(),
            format!("{:+.2e}", tl.slope),
            tl.mass.to_string(),
        ]);
    }
    for tl in sorted.iter().rev().take(top).rev() {
        t.row([
            "falling".to_string(),
            tl.topic.to_string(),
            (first_year + tl.peak as u32).to_string(),
            format!("{:+.2e}", tl.slope),
            tl.mass.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts_with_planted_trends() -> (BotCounts, BotHyper) {
        // 2 topics, 10 stamps: topic 0 concentrated early, topic 1 late.
        let k = 2;
        let s = 10;
        let mut c = BotCounts::zeros(1, 1, s, k);
        for st in 0..s {
            let early = ((s - st) * 10) as u32;
            let late = (st * 10) as u32;
            c.stamp_topic[st * k] = early as f32;
            c.stamp_topic[st * k + 1] = late as f32;
            c.topic_stamps[0] += early;
            c.topic_stamps[1] += late;
        }
        (c, BotHyper::new(k, 0.5, 0.1, 0.1, 1, s))
    }

    #[test]
    fn pi_normalizes() {
        let (c, h) = counts_with_planted_trends();
        for tl in timelines(&c, &h) {
            let sum: f64 = tl.pi.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "pi sums to {sum}");
        }
    }

    #[test]
    fn detects_rising_and_falling() {
        let (c, h) = counts_with_planted_trends();
        let tls = timelines(&c, &h);
        assert!(tls[0].slope < 0.0, "topic 0 should fall");
        assert!(tls[1].slope > 0.0, "topic 1 should rise");
        assert_eq!(tls[0].peak, 0);
        assert_eq!(tls[1].peak, 9);
    }

    #[test]
    fn trend_table_lists_both_directions() {
        let (c, h) = counts_with_planted_trends();
        let tls = timelines(&c, &h);
        let t = trend_table(&tls, 1951, 1);
        assert_eq!(t.num_rows(), 2);
        let s = t.to_aligned();
        assert!(s.contains("rising") && s.contains("falling"));
    }

    #[test]
    fn slope_of_constant_is_zero() {
        assert_eq!(linear_slope(&[0.5, 0.5, 0.5]), 0.0);
        assert_eq!(linear_slope(&[1.0]), 0.0);
    }
}
