//! The paper's *alternative* BoT parallelization (§IV-C): "Another
//! approach is to merge the timestamp array into the document content,
//! then partition and sample both words and timestamps in one matrix."
//!
//! Timestamps are appended to the vocabulary as `W + s` pseudo-words, the
//! merged document–word matrix is partitioned once, and a single diagonal
//! sweep per epoch samples words and timestamps together. The emission
//! distributions stay separate (β/Wβ for real words, γ/Sγ for timestamp
//! pseudo-words), so the model is identical to the two-matrix variant —
//! only the partitioning/scheduling changes:
//!
//! * one partition plan instead of two (simpler, one η),
//! * timestamp mass can balance word mass inside a partition (helps when
//!   the DTS matrix alone is hard to balance, e.g. few timestamp columns
//!   at large P — see EXPERIMENTS.md Table IV's η_DTS discussion),
//! * the per-token kernel needs a branch on word id (paper chose the
//!   two-matrix form "for its simplicity").

use crate::bot::counts::BotCounts;
use crate::bot::serial::BotHyper;
use crate::corpus::bow::{BagOfWords, Entry};
use crate::corpus::timestamps::TimestampedCorpus;
use crate::gibbs::sampler::draw;
use crate::gibbs::tokens::TokenBlock;
use crate::partition::scheme::PartitionMap;
use crate::partition::{self, Algorithm, Plan};
use crate::util::rng::Rng;

/// Merge DW and DTS into one matrix with timestamps as pseudo-words
/// `W..W+S`.
pub fn merge_matrices(tc: &TimestampedCorpus) -> BagOfWords {
    let w = tc.bow.num_words();
    let rows: Vec<Vec<Entry>> = (0..tc.bow.num_docs())
        .map(|j| {
            let mut row: Vec<Entry> = tc.bow.doc(j).to_vec();
            row.extend(tc.dts.doc(j).iter().map(|e| Entry {
                word: w as u32 + e.word,
                count: e.count,
            }));
            row
        })
        .collect();
    BagOfWords::from_rows(w + tc.num_stamps, rows)
}

/// Parallel BoT over the merged matrix: one plan, one diagonal sweep per
/// epoch, mixed word/timestamp tokens per partition.
pub struct MergedBot {
    pub h: BotHyper,
    pub counts: BotCounts,
    pub p: usize,
    pub plan_eta: f64,
    /// Mixed blocks, diagonal-major over the merged plan.
    blocks: Vec<Vec<TokenBlock>>,
    num_words: usize,
    seed: u64,
    sweeps_done: usize,
    probs: Vec<f32>,
}

impl MergedBot {
    pub fn init(
        tc: &TimestampedCorpus,
        p: usize,
        algo: Algorithm,
        h: BotHyper,
        seed: u64,
    ) -> Self {
        let merged = merge_matrices(tc);
        let plan: Plan = partition::partition(&merged, p, algo, seed);
        let map = PartitionMap::build(&merged, &plan);
        let mut rng = Rng::stream(seed, 0x3E26ED);

        let mut blocks = Vec::with_capacity(p);
        for l in 0..p {
            blocks.push(
                map.diagonal(l)
                    .map(|(m, n)| TokenBlock::from_cells(map.cells(m, n), h.k, &mut rng))
                    .collect::<Vec<_>>(),
            );
        }

        let w = tc.bow.num_words();
        let mut counts = BotCounts::zeros(merged.num_docs(), w, tc.num_stamps, h.k);
        for diag in &blocks {
            for b in diag {
                for i in 0..b.len() {
                    let (d, x, z) = (
                        b.docs[i] as usize,
                        b.words[i] as usize,
                        b.z[i] as usize,
                    );
                    counts.doc_topic[d * h.k + z] += 1.0;
                    if x < w {
                        counts.word_topic[x * h.k + z] += 1.0;
                        counts.topic_words[z] += 1;
                    } else {
                        counts.stamp_topic[(x - w) * h.k + z] += 1.0;
                        counts.topic_stamps[z] += 1;
                    }
                }
            }
        }
        Self {
            h,
            counts,
            p,
            plan_eta: plan.eta,
            blocks,
            num_words: w,
            seed,
            sweeps_done: 0,
        probs: Vec::new(),
        }
    }

    /// One sweep: `P` diagonal epochs over the merged matrix. Executed
    /// sequentially per worker (the merged kernel is branchy; this
    /// variant exists for η/quality comparison — see `merged_vs_two_matrix`
    /// tests — not as the perf path).
    pub fn sweep(&mut self) {
        let p = self.p;
        for l in 0..p {
            for m in 0..p {
                // Split borrows: blocks vs counts.
                let block = {
                    let diag = &mut self.blocks[l];
                    std::mem::take(&mut diag[m])
                };
                let mut block = block;
                let mut rng = Rng::stream(
                    self.seed ^ 0x3E26,
                    ((self.sweeps_done as u64) << 24) | ((l as u64) << 12) | m as u64,
                );
                self.sweep_block(&mut block, &mut rng);
                self.blocks[l][m] = block;
            }
        }
        self.sweeps_done += 1;
    }

    fn sweep_block(&mut self, block: &mut TokenBlock, rng: &mut Rng) {
        let k = self.h.k;
        let w = self.num_words;
        self.probs.resize(k, 0.0);
        for i in 0..block.len() {
            let d = block.docs[i] as usize;
            let x = block.words[i] as usize;
            let old = block.z[i] as usize;
            let is_word = x < w;

            self.counts.doc_topic[d * k + old] -= 1.0;
            if is_word {
                self.counts.word_topic[x * k + old] -= 1.0;
                self.counts.topic_words[old] -= 1;
            } else {
                self.counts.stamp_topic[(x - w) * k + old] -= 1.0;
                self.counts.topic_stamps[old] -= 1;
            }

            let mut total = 0.0f32;
            for t in 0..k {
                let theta = self.counts.doc_topic[d * k + t] + self.h.alpha;
                let emit = if is_word {
                    (self.counts.word_topic[x * k + t] + self.h.beta)
                        / (self.counts.topic_words[t] as f32 + self.h.wbeta)
                } else {
                    (self.counts.stamp_topic[(x - w) * k + t] + self.h.gamma)
                        / (self.counts.topic_stamps[t] as f32 + self.h.sgamma)
                };
                let pr = theta * emit;
                self.probs[t] = pr;
                total += pr;
            }
            let new = draw(&self.probs, total, rng);

            self.counts.doc_topic[d * k + new] += 1.0;
            if is_word {
                self.counts.word_topic[x * k + new] += 1.0;
                self.counts.topic_words[new] += 1;
            } else {
                self.counts.stamp_topic[(x - w) * k + new] += 1.0;
                self.counts.topic_stamps[new] += 1;
            }
            block.z[i] = new as u32;
        }
    }

    pub fn train(&mut self, iters: usize) {
        for _ in 0..iters {
            self.sweep();
        }
    }

    /// Table IV metric: word perplexity (identical definition to the
    /// two-matrix variant).
    pub fn perplexity(&self, tc: &TimestampedCorpus) -> f64 {
        super::perplexity_words(&tc.bow, &self.counts, &self.h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bot::serial::SerialBot;
    use crate::corpus::synthetic::{generate_timestamped, Profile, TimeProfile};

    fn tiny_tc(seed: u64) -> TimestampedCorpus {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        generate_timestamped(&p, seed)
    }

    #[test]
    fn merged_matrix_preserves_totals() {
        let tc = tiny_tc(71);
        let merged = merge_matrices(&tc);
        assert_eq!(merged.num_docs(), tc.bow.num_docs());
        assert_eq!(merged.num_words(), tc.bow.num_words() + tc.num_stamps);
        assert_eq!(merged.num_tokens(), tc.total_tokens());
        // Per-doc: word entries preserved, stamp mass appended.
        for j in 0..merged.num_docs() {
            assert_eq!(
                merged.row_sum(j),
                tc.bow.row_sum(j) + tc.dts.row_sum(j)
            );
        }
    }

    #[test]
    fn merged_bot_conserves_counts_and_learns() {
        let tc = tiny_tc(72);
        let h = BotHyper::new(8, 0.5, 0.1, 0.1, tc.bow.num_words(), tc.num_stamps);
        let mut bot = MergedBot::init(&tc, 4, Algorithm::A3 { restarts: 3 }, h, 72);
        assert_eq!(bot.counts.total(), tc.total_tokens());
        let p0 = bot.perplexity(&tc);
        bot.train(25);
        assert_eq!(bot.counts.total(), tc.total_tokens());
        let p1 = bot.perplexity(&tc);
        assert!(p1 < p0 * 0.9, "{p0} → {p1}");
    }

    #[test]
    fn merged_vs_two_matrix_perplexity_close() {
        // Same model, different scheduling: converged quality must agree
        // (the paper's argument for choosing either variant freely).
        let tc = tiny_tc(73);
        let h = BotHyper::new(8, 0.5, 0.1, 0.1, tc.bow.num_words(), tc.num_stamps);
        let mut merged = MergedBot::init(&tc, 4, Algorithm::A3 { restarts: 3 }, h, 73);
        merged.train(30);
        let mut serial = SerialBot::init(&tc, h, 73);
        serial.train(&tc, 30, 0);
        let (pm, ps) = (merged.perplexity(&tc), serial.perplexity(&tc));
        let rel = (pm - ps).abs() / ps;
        assert!(rel < 0.06, "merged {pm} vs serial {ps} (rel {rel})");
    }

    #[test]
    fn merged_single_eta_reported() {
        let tc = tiny_tc(74);
        let h = BotHyper::new(4, 0.5, 0.1, 0.1, tc.bow.num_words(), tc.num_stamps);
        let bot = MergedBot::init(&tc, 5, Algorithm::A1, h, 74);
        assert!(bot.plan_eta > 0.0 && bot.plan_eta <= 1.0 + 1e-12);
    }
}
