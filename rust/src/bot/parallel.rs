//! Parallel BoT (paper §IV-C): each sweep epoch samples one diagonal of
//! `DW` (word phase) and then the corresponding diagonal of `DTS`
//! (timestamp phase), both conflict-free under their own partition plans.

use std::time::Instant;

use crate::bot::counts::BotCounts;
use crate::bot::serial::BotHyper;
use crate::corpus::timestamps::TimestampedCorpus;
use crate::gibbs::sampler;
use crate::gibbs::tokens::TokenBlock;
use crate::partition::scheme::PartitionMap;
use crate::partition::Plan;
use crate::scheduler::exec::{ExecMode, SweepStats};
use crate::scheduler::shared::SharedRows;
use crate::util::rng::Rng;

pub struct ParallelBot {
    pub h: BotHyper,
    pub counts: BotCounts,
    pub p: usize,
    /// Word blocks, diagonal-major over the DW plan.
    word_blocks: Vec<Vec<TokenBlock>>,
    /// Timestamp blocks, diagonal-major over the DTS plan.
    stamp_blocks: Vec<Vec<TokenBlock>>,
    seed: u64,
    sweeps_done: usize,
}

impl ParallelBot {
    /// `plan_dw` partitions the document–word matrix, `plan_dts` the
    /// document–timestamp matrix (independent plans over R and R', as the
    /// paper prescribes). Both must use the same `P`.
    pub fn init(
        tc: &TimestampedCorpus,
        plan_dw: &Plan,
        plan_dts: &Plan,
        h: BotHyper,
        seed: u64,
    ) -> Self {
        assert_eq!(plan_dw.p, plan_dts.p, "DW and DTS plans must share P");
        let p = plan_dw.p;
        let mut rng = Rng::stream(seed, 0xB07_11);

        let build = |bow, plan: &Plan, rng: &mut Rng| {
            let map = PartitionMap::build(bow, plan);
            (0..p)
                .map(|l| {
                    map.diagonal(l)
                        .map(|(m, n)| TokenBlock::from_cells(map.cells(m, n), h.k, rng))
                        .collect::<Vec<_>>()
                })
                .collect::<Vec<_>>()
        };
        let word_blocks = build(&tc.bow, plan_dw, &mut rng);
        let stamp_blocks = build(&tc.dts, plan_dts, &mut rng);

        let mut counts = BotCounts::zeros(
            tc.bow.num_docs(),
            tc.bow.num_words(),
            tc.num_stamps,
            h.k,
        );
        for diag in &word_blocks {
            for b in diag {
                counts.absorb_words(b);
            }
        }
        for diag in &stamp_blocks {
            for b in diag {
                counts.absorb_stamps(b);
            }
        }
        Self {
            h,
            counts,
            p,
            word_blocks,
            stamp_blocks,
            seed,
            sweeps_done: 0,
        }
    }

    /// One sweep: `P` epochs of (word diagonal, then timestamp diagonal).
    /// Returns (word stats, stamp stats).
    pub fn sweep(&mut self, mode: ExecMode) -> (SweepStats, SweepStats) {
        let p = self.p;
        let k = self.h.k;
        let sweep_no = self.sweeps_done;
        let mut wstats = SweepStats::default();
        let mut sstats = SweepStats::default();

        for l in 0..p {
            // ---- word phase on DW diagonal l ----
            {
                let snapshot = self.counts.topic_words.clone();
                let started = Instant::now();
                let diag = &mut self.word_blocks[l];
                wstats
                    .epoch_max_tokens
                    .push(diag.iter().map(|b| b.len() as u64).max().unwrap_or(0));
                wstats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
                let doc_rows = SharedRows::new(&mut self.counts.doc_topic, k);
                let emit_rows = SharedRows::new(&mut self.counts.word_topic, k);
                let h = self.h.word_hyper();
                let deltas = run_diagonal(
                    diag,
                    doc_rows,
                    emit_rows,
                    &snapshot,
                    &h,
                    self.seed ^ 0xD0C5,
                    sweep_no,
                    l,
                    mode,
                );
                merge(&mut self.counts.topic_words, deltas);
                wstats.epoch_secs.push(started.elapsed().as_secs_f64());
            }

            // ---- timestamp phase on DTS diagonal l ----
            {
                let snapshot = self.counts.topic_stamps.clone();
                let started = Instant::now();
                let diag = &mut self.stamp_blocks[l];
                sstats
                    .epoch_max_tokens
                    .push(diag.iter().map(|b| b.len() as u64).max().unwrap_or(0));
                sstats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
                let doc_rows = SharedRows::new(&mut self.counts.doc_topic, k);
                let emit_rows = SharedRows::new(&mut self.counts.stamp_topic, k);
                let h = self.h.stamp_hyper();
                let deltas = run_diagonal(
                    diag,
                    doc_rows,
                    emit_rows,
                    &snapshot,
                    &h,
                    self.seed ^ 0x7135,
                    sweep_no,
                    l,
                    mode,
                );
                merge(&mut self.counts.topic_stamps, deltas);
                sstats.epoch_secs.push(started.elapsed().as_secs_f64());
            }
        }
        self.sweeps_done += 1;
        (wstats, sstats)
    }

    pub fn train(
        &mut self,
        tc: &TimestampedCorpus,
        iters: usize,
        eval_every: usize,
        mode: ExecMode,
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for it in 1..=iters {
            self.sweep(mode);
            if eval_every > 0 && (it % eval_every == 0 || it == iters) {
                curve.push((it, self.perplexity(tc)));
            }
        }
        curve
    }

    /// Table IV metric: word perplexity.
    pub fn perplexity(&self, tc: &TimestampedCorpus) -> f64 {
        super::perplexity_words(&tc.bow, &self.counts, &self.h)
    }

    pub fn word_blocks_flat(&self) -> Vec<&TokenBlock> {
        self.word_blocks.iter().flatten().collect()
    }

    pub fn stamp_blocks_flat(&self) -> Vec<&TokenBlock> {
        self.stamp_blocks.iter().flatten().collect()
    }
}

/// Run one diagonal's workers (threaded or sequential) and collect their
/// topic-total deltas.
#[allow(clippy::too_many_arguments)]
fn run_diagonal(
    diag: &mut [TokenBlock],
    doc_rows: SharedRows<'_>,
    emit_rows: SharedRows<'_>,
    snapshot: &[u32],
    h: &sampler::Hyper,
    seed: u64,
    sweep_no: usize,
    l: usize,
    mode: ExecMode,
) -> Vec<Vec<i64>> {
    let k = h.k;
    let worker = |m: usize, block: &mut TokenBlock| {
        let mut delta = vec![0i64; k];
        let mut probs = Vec::new();
        let mut rng = Rng::stream(
            seed,
            ((sweep_no as u64) << 24) | ((l as u64) << 12) | m as u64,
        );
        sampler::sweep_partition(
            block,
            // SAFETY: diagonal non-conflict — block tokens lie in
            // partition (m, (m+l) mod P) of this phase's plan; its doc
            // group and emission group rows are exclusive to this worker
            // for the epoch.
            |d| unsafe { doc_rows.row_ptr(d) },
            |w| unsafe { emit_rows.row_ptr(w) },
            snapshot,
            &mut delta,
            h,
            &mut rng,
            &mut probs,
        );
        delta
    };
    match mode {
        ExecMode::Sequential => diag
            .iter_mut()
            .enumerate()
            .map(|(m, b)| worker(m, b))
            .collect(),
        ExecMode::Threaded => std::thread::scope(|s| {
            let handles: Vec<_> = diag
                .iter_mut()
                .enumerate()
                .map(|(m, b)| {
                    let worker = &worker;
                    s.spawn(move || worker(m, b))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        }),
    }
}

fn merge(totals: &mut [u32], deltas: Vec<Vec<i64>>) {
    for delta in deltas {
        for (t, d) in delta.into_iter().enumerate() {
            let v = totals[t] as i64 + d;
            debug_assert!(v >= 0, "topic total went negative");
            totals[t] = v as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate_timestamped, Profile, TimeProfile};
    use crate::partition::{partition, Algorithm};

    fn tiny_tc(seed: u64) -> TimestampedCorpus {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        generate_timestamped(&p, seed)
    }

    fn setup(p: usize, seed: u64) -> (TimestampedCorpus, ParallelBot) {
        let tc = tiny_tc(seed);
        let plan_dw = partition(&tc.bow, p, Algorithm::A3 { restarts: 3 }, seed);
        let plan_dts = partition(&tc.dts, p, Algorithm::A3 { restarts: 3 }, seed + 1);
        let h = super::super::serial::BotHyper::new(
            8,
            0.5,
            0.1,
            0.1,
            tc.bow.num_words(),
            tc.num_stamps,
        );
        let bot = ParallelBot::init(&tc, &plan_dw, &plan_dts, h, seed);
        (tc, bot)
    }

    #[test]
    fn init_covers_both_matrices() {
        let (tc, bot) = setup(3, 61);
        assert_eq!(bot.counts.total(), tc.total_tokens());
        assert!(bot
            .counts
            .check_consistency(&bot.word_blocks_flat(), &bot.stamp_blocks_flat())
            .is_ok());
    }

    #[test]
    fn sweep_preserves_invariants() {
        let (tc, mut bot) = setup(3, 62);
        for _ in 0..3 {
            let (ws, ss) = bot.sweep(ExecMode::Sequential);
            assert_eq!(ws.total_tokens, tc.bow.num_tokens());
            assert_eq!(ss.total_tokens, tc.dts.num_tokens());
        }
        assert_eq!(bot.counts.total(), tc.total_tokens());
        assert!(bot
            .counts
            .check_consistency(&bot.word_blocks_flat(), &bot.stamp_blocks_flat())
            .is_ok());
    }

    #[test]
    fn threaded_equals_sequential() {
        let (_tc, mut a) = setup(4, 63);
        let (_tc2, mut b) = setup(4, 63);
        for _ in 0..2 {
            a.sweep(ExecMode::Threaded);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.stamp_topic, b.counts.stamp_topic);
    }

    #[test]
    fn parallel_bot_close_to_serial_bot() {
        // Table IV in miniature: perplexities approximately equal.
        let (tc, mut par) = setup(4, 64);
        let h = par.h;
        let mut ser = super::super::serial::SerialBot::init(&tc, h, 64);
        par.train(&tc, 30, 0, ExecMode::Sequential);
        ser.train(&tc, 30, 0);
        let pp = par.perplexity(&tc);
        let ps = ser.perplexity(&tc);
        let rel = (pp - ps).abs() / ps;
        assert!(rel < 0.05, "parallel {pp} vs serial {ps} (rel {rel})");
    }
}
