//! Parallel BoT (paper §IV-C): each sweep epoch samples one diagonal of
//! `DW` (word phase) and then the corresponding diagonal of `DTS`
//! (timestamp phase), both conflict-free under their own partition plans
//! and both scheduled onto the same `W` workers (each plan gets its own
//! LPT packing, since their cost matrices differ).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::bot::counts::BotCounts;
use crate::bot::serial::BotHyper;
use crate::corpus::shard::{Residency, ShardedBlocks, ShardStore};
use crate::corpus::timestamps::TimestampedCorpus;
use crate::gibbs::tokens::TokenBlock;
use crate::kernel::KernelKind;
use crate::obs::metrics::{Family, Phase as MetricPhase, Registry};
use crate::obs::trace::{Event, EventKind, Tracer};
use crate::partition::eta::CostMatrix;
use crate::partition::scheme::PartitionMap;
use crate::partition::Plan;
use crate::scheduler::adaptive::{BalanceMode, Measured};
use crate::scheduler::exec::{build_blocks, CommitMode, ExecMode, SweepStats};
use crate::scheduler::pool::{
    commit_delta, merge_deltas, EngineCache, EpochSpec, EpochTasks, Executor, TaskObs, WorkerPool,
};
use crate::scheduler::schedule::{partition_id, Schedule, ScheduleKind};
use crate::scheduler::shared::SharedRows;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Salt folded into the base seed for the *word* phase's task RNG keys
/// (`task seed = trainer seed ^ BOT_WORD_SALT`), keeping the DW and DTS
/// phases on disjoint RNG streams even though they share a sweep
/// counter. Fault-injection keys for word-phase tasks lead with this
/// salted seed (see `util::fault`).
pub(crate) const BOT_WORD_SALT: u64 = 0xD0C5;

/// Salt folded into the base seed for the *timestamp* phase's task RNG
/// keys — the DTS counterpart of [`BOT_WORD_SALT`].
pub(crate) const BOT_STAMP_SALT: u64 = 0x7135;

/// Diagonal-major token blocks (under a residency policy) plus schedule
/// and cost state for one matrix.
struct Phase {
    shards: ShardedBlocks,
    costs: CostMatrix,
    schedule: Schedule,
    /// Measured per-partition cost estimator for this phase's plan (the
    /// DW and DTS grids have independent cost structure, so each phase
    /// learns — and repacks — on its own).
    estimator: Measured,
}

impl Phase {
    /// Build the phase's blocks diagonal by diagonal through the shared
    /// [`build_blocks`] helper (each block is absorbed into the counts
    /// before the residency policy decides whether it stays in RAM, so
    /// spill-mode init peaks at one diagonal per phase). `store_tag`
    /// names the phase's temp spill dir.
    #[allow(clippy::too_many_arguments)]
    fn build(
        bow: &crate::corpus::bow::BagOfWords,
        plan: &Plan,
        k: usize,
        rng: &mut Rng,
        kind: ScheduleKind,
        workers: usize,
        residency: Residency,
        store_tag: &str,
        absorb: impl FnMut(&TokenBlock),
    ) -> Result<Self> {
        let p = plan.p;
        let map = PartitionMap::build(bow, plan);
        let shards = build_blocks(&map, p, k, rng, residency, store_tag, absorb)?;
        Ok(Self {
            shards,
            costs: plan.costs.clone(),
            schedule: Schedule::build(kind, &plan.costs, workers),
            estimator: Measured::new(p),
        })
    }

    /// Rebuild the phase by verified-reading every partition's block out
    /// of a checkpoint store `src` (CRC32 checksums plus the `expected`
    /// sweep stamp), re-absorbing the counts, and building a fresh block
    /// container under `residency` — the BoT half of the copy-out resume
    /// path (see `ParallelLda::resume_from_store`). `src` is left
    /// untouched for future resumes.
    #[allow(clippy::too_many_arguments)]
    fn resume(
        bow: &crate::corpus::bow::BagOfWords,
        plan: &Plan,
        kind: ScheduleKind,
        workers: usize,
        residency: Residency,
        store_tag: &str,
        src: &ShardStore,
        expected: u64,
        mut absorb: impl FnMut(&TokenBlock),
    ) -> Result<Self> {
        let p = plan.p;
        let map = PartitionMap::build(bow, plan);
        let mut shards = match residency {
            Residency::InCore => ShardedBlocks::in_core(),
            Residency::Spill { budget_bytes } => {
                ShardedBlocks::spill(ShardStore::create_temp(store_tag)?, budget_bytes)
            }
        };
        // Blocks re-spilled while rebuilding must carry the checkpoint's
        // stamp, preserving the at-rest invariant until the next sweep
        // bumps it.
        shards.set_stamp(expected);
        for l in 0..p {
            let ids: Vec<u64> = map.diagonal(l).map(|(m, n)| partition_id(m, n, p)).collect();
            let mut diag = Vec::with_capacity(ids.len());
            for &id in &ids {
                let b = src.read_block_verified(id, expected)?;
                absorb(&b);
                diag.push(b);
            }
            shards.push_diagonal(diag, ids)?;
        }
        Ok(Self {
            shards,
            costs: plan.costs.clone(),
            schedule: Schedule::build(kind, &plan.costs, workers),
            estimator: Measured::new(p),
        })
    }
}

pub struct ParallelBot {
    pub h: BotHyper,
    pub counts: BotCounts,
    /// Grid size `P` shared by both plans.
    pub p: usize,
    /// Word blocks + schedule over the DW plan.
    word: Phase,
    /// Timestamp blocks + schedule over the DTS plan.
    stamp: Phase,
    /// Sampling kernel both phases run (see [`crate::kernel`]): the
    /// timestamp phase reuses the doc-side sparse structures unchanged —
    /// the timestamp factor enters the bucket weights through the phase
    /// [`crate::gibbs::sampler::Hyper`] (γ for β, S·γ for W·β).
    kernel: KernelKind,
    /// Load-balancing strategy shared by both phases (see
    /// [`crate::scheduler::adaptive`]); result-invariant.
    balance: BalanceMode,
    /// Delta-commit protocol shared by both phases (see
    /// [`crate::scheduler::exec::CommitMode`]); result-invariant.
    commit: CommitMode,
    /// The residency policy as configured (each phase holds half the
    /// spill budget; this keeps the caller's original value).
    residency: Residency,
    seed: u64,
    sweeps_done: usize,
    /// Executor state — the persistent pool (if `Pooled` mode is used)
    /// serves *both* phases' epochs, since they share `W` and `K`.
    engines: EngineCache,
    /// Double-buffered epoch-start views of `counts.topic_words` /
    /// `counts.topic_stamps` (no per-epoch clone).
    word_snapshot: Vec<u32>,
    stamp_snapshot: Vec<u32>,
    /// Per-task signed topic deltas, shared by both phases.
    deltas: Vec<Vec<i64>>,
    /// Per-task measured nanos (telemetry scratch, shared by phases).
    task_nanos: Vec<u64>,
    /// Per-worker busy nanos (telemetry scratch, shared by phases).
    worker_nanos: Vec<u64>,
    /// Structured tracer, when attached (`--trace-out`). Strictly
    /// observational; word tasks carry family 0, timestamp tasks
    /// family 1.
    tracer: Option<Arc<Tracer>>,
    /// Metrics registry both phases account into (word = `Family::Word`,
    /// timestamp = `Family::Stamp`); the per-sweep `SweepStats` pairs
    /// and the report `PhaseTimer` are views over it.
    metrics: Registry,
}

impl ParallelBot {
    /// `plan_dw` partitions the document–word matrix, `plan_dts` the
    /// document–timestamp matrix (independent plans over R and R', as the
    /// paper prescribes). Both must use the same `P`; execution uses the
    /// legacy diagonal schedule (`W == P`).
    pub fn init(
        tc: &TimestampedCorpus,
        plan_dw: &Plan,
        plan_dts: &Plan,
        h: BotHyper,
        seed: u64,
    ) -> Self {
        Self::init_scheduled(tc, plan_dw, plan_dts, h, seed, ScheduleKind::Diagonal, plan_dw.p)
    }

    /// As [`Self::init`], but mapping both grids onto `workers` worker
    /// slots under `kind`. Each phase is packed against its own cost
    /// matrix. Token initialization is schedule-independent, so any
    /// `(kind, workers)` over the same plans trains bit-identically.
    pub fn init_scheduled(
        tc: &TimestampedCorpus,
        plan_dw: &Plan,
        plan_dts: &Plan,
        h: BotHyper,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
    ) -> Self {
        Self::init_resident(tc, plan_dw, plan_dts, h, seed, kind, workers, Residency::InCore)
            .expect("in-core init performs no IO")
    }

    /// As [`Self::init_scheduled`], with an explicit [`Residency`]. Under
    /// `Spill` each phase spills to its own temp
    /// [`crate::corpus::shard::ShardStore`] (the DW and DTS grids have
    /// independent partition-id spaces) and the byte budget is split
    /// evenly between the phases. Residency never changes results — see
    /// [`crate::corpus::shard`].
    #[allow(clippy::too_many_arguments)]
    pub fn init_resident(
        tc: &TimestampedCorpus,
        plan_dw: &Plan,
        plan_dts: &Plan,
        h: BotHyper,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
        residency: Residency,
    ) -> Result<Self> {
        assert_eq!(plan_dw.p, plan_dts.p, "DW and DTS plans must share P");
        let p = plan_dw.p;
        let mut rng = Rng::stream(seed, 0xB07_11);
        let phase_residency = match residency {
            Residency::InCore => Residency::InCore,
            Residency::Spill { budget_bytes } => Residency::Spill {
                budget_bytes: budget_bytes / 2,
            },
        };

        let mut counts = BotCounts::zeros(
            tc.bow.num_docs(),
            tc.bow.num_words(),
            tc.num_stamps,
            h.k,
        );
        let word = Phase::build(
            &tc.bow,
            plan_dw,
            h.k,
            &mut rng,
            kind,
            workers,
            phase_residency,
            "bot-word",
            |b| counts.absorb_words(b),
        )?;
        let stamp = Phase::build(
            &tc.dts,
            plan_dts,
            h.k,
            &mut rng,
            kind,
            workers,
            phase_residency,
            "bot-stamp",
            |b| counts.absorb_stamps(b),
        )?;
        Ok(Self {
            h,
            counts,
            p,
            word,
            stamp,
            kernel: KernelKind::Dense,
            balance: BalanceMode::Static,
            commit: CommitMode::default(),
            residency,
            seed,
            sweeps_done: 0,
            engines: EngineCache::new(workers),
            word_snapshot: vec![0; h.k],
            stamp_snapshot: vec![0; h.k],
            deltas: vec![vec![0i64; h.k]; p],
            task_nanos: vec![0; p],
            worker_nanos: vec![0; workers],
            tracer: None,
            metrics: Registry::new(),
        })
    }

    /// Rebuild a BoT trainer by *copying* blocks out of a pair of
    /// checkpoint stores — `dw_store` holding the word-phase partitions,
    /// `dts_store` the timestamp-phase ones. Every block is
    /// verified-read (CRC32 checksums plus the `sweeps_done` stamp), the
    /// count matrices are reconstructed exactly by re-absorption, and
    /// fresh block containers are built under `residency`, leaving both
    /// checkpoint stores untouched for future resumes. Task RNG streams
    /// depend only on `(seed, sweep, partition)` per phase, so training
    /// continues bit-identically to an uninterrupted run. The checkpoint
    /// drivers in `crate::coordinator::checkpoint` resume through this.
    #[allow(clippy::too_many_arguments)]
    pub fn resume_from_store(
        tc: &TimestampedCorpus,
        plan_dw: &Plan,
        plan_dts: &Plan,
        h: BotHyper,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
        dw_store: &ShardStore,
        dts_store: &ShardStore,
        sweeps_done: usize,
        residency: Residency,
    ) -> Result<Self> {
        assert_eq!(plan_dw.p, plan_dts.p, "DW and DTS plans must share P");
        let p = plan_dw.p;
        let phase_residency = match residency {
            Residency::InCore => Residency::InCore,
            Residency::Spill { budget_bytes } => Residency::Spill {
                budget_bytes: budget_bytes / 2,
            },
        };
        let expected = sweeps_done as u64;
        let mut counts = BotCounts::zeros(
            tc.bow.num_docs(),
            tc.bow.num_words(),
            tc.num_stamps,
            h.k,
        );
        let word = Phase::resume(
            &tc.bow,
            plan_dw,
            kind,
            workers,
            phase_residency,
            "bot-word",
            dw_store,
            expected,
            |b| counts.absorb_words(b),
        )?;
        let stamp = Phase::resume(
            &tc.dts,
            plan_dts,
            kind,
            workers,
            phase_residency,
            "bot-stamp",
            dts_store,
            expected,
            |b| counts.absorb_stamps(b),
        )?;
        Ok(Self {
            h,
            counts,
            p,
            word,
            stamp,
            kernel: KernelKind::Dense,
            balance: BalanceMode::Static,
            commit: CommitMode::default(),
            residency,
            seed,
            sweeps_done,
            engines: EngineCache::new(workers),
            word_snapshot: vec![0; h.k],
            stamp_snapshot: vec![0; h.k],
            deltas: vec![vec![0i64; h.k]; p],
            task_nanos: vec![0; p],
            worker_nanos: vec![0; workers],
            tracer: None,
            metrics: Registry::new(),
        })
    }

    /// Sweeps completed so far. This is the checkpoint coordinate: task
    /// RNG streams for sweep `s` depend only on `(phase seed, s,
    /// partition)`, never on how the trainer reached sweep `s`.
    pub fn sweeps_done(&self) -> usize {
        self.sweeps_done
    }

    /// The base RNG seed this trainer was initialized with (the phase
    /// salts [`BOT_WORD_SALT`] / [`BOT_STAMP_SALT`] are folded in per
    /// epoch, not stored).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The live spill directories of the (word, timestamp) phases, if
    /// spilling (`None` per phase when in-core).
    pub fn spill_dirs(&self) -> (Option<&Path>, Option<&Path>) {
        (self.word.shards.store_path(), self.stamp.shards.store_path())
    }

    /// Export every partition's current state into per-phase checkpoint
    /// stores, stamped with the completed sweep count — the BoT
    /// checkpoint primitive (see `crate::coordinator::checkpoint`). The
    /// trainer is unchanged. Call between sweeps only (the at-rest stamp
    /// equals `sweeps_done` there).
    pub fn export_blocks(&self, dw: &ShardStore, dts: &ShardStore) -> Result<()> {
        self.word.shards.export_to(dw)?;
        self.stamp.shards.export_to(dts)?;
        Ok(())
    }

    /// Re-map both plans onto a different worker count / schedule kind
    /// mid-training; results are unaffected (partition-keyed RNG) but the
    /// executor state is rebuilt for the new worker count.
    pub fn set_schedule(&mut self, kind: ScheduleKind, workers: usize) {
        self.word.schedule = Schedule::build(kind, &self.word.costs, workers);
        self.stamp.schedule = Schedule::build(kind, &self.stamp.costs, workers);
        self.engines = EngineCache::new(workers);
        self.worker_nanos = vec![0; workers];
        if self.balance == BalanceMode::Adaptive {
            self.word.estimator.repack(&mut self.word.schedule, &self.word.costs);
            self.stamp.estimator.repack(&mut self.stamp.schedule, &self.stamp.costs);
        }
    }

    /// Worker slots the current schedules run on.
    pub fn workers(&self) -> usize {
        self.word.schedule.workers
    }

    /// Attach (or detach) a structured tracer. Subsequent sweeps emit
    /// per-task spans (word phase = family 0, timestamp phase =
    /// family 1) and commit spans into its ring buffers, drained at
    /// each sweep boundary. Strictly observational: results are
    /// bit-identical with or without it.
    pub fn set_tracer(&mut self, tracer: Option<Arc<Tracer>>) {
        self.tracer = tracer;
    }

    /// The attached tracer, if any.
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// The trainer's metrics registry (both phases account into it;
    /// the report phase breakdown is a view over this).
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Select the sampling kernel for both phases of subsequent sweeps.
    pub fn set_kernel(&mut self, kernel: KernelKind) {
        self.kernel = kernel;
    }

    /// The kernel running this trainer's sweeps.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Select the load-balancing strategy for both phases (see
    /// [`crate::scheduler::adaptive`]). Result-invariant: only which
    /// worker samples which partition — and therefore wallclock —
    /// changes.
    pub fn set_balance(&mut self, balance: BalanceMode) {
        if self.balance == balance {
            return;
        }
        self.balance = balance;
        match balance {
            BalanceMode::Adaptive => {
                self.word.estimator.repack(&mut self.word.schedule, &self.word.costs);
                self.stamp.estimator.repack(&mut self.stamp.schedule, &self.stamp.costs);
            }
            BalanceMode::Static | BalanceMode::Steal => {
                let wc = &self.word.costs;
                self.word.schedule.repack_with(|m, n| wc.get(m, n));
                let sc = &self.stamp.costs;
                self.stamp.schedule.repack_with(|m, n| sc.get(m, n));
            }
        }
    }

    /// The balance mode governing this trainer's sweeps.
    pub fn balance(&self) -> BalanceMode {
        self.balance
    }

    /// Select the delta-commit protocol for both phases of subsequent
    /// sweeps (see [`CommitMode`]). Result-invariant: `Ticketed` folds
    /// each task's delta in ticket order against the same epoch-start
    /// snapshots the barrier protocol uses.
    pub fn set_commit(&mut self, commit: CommitMode) {
        self.commit = commit;
    }

    /// The commit protocol governing this trainer's sweeps.
    pub fn commit(&self) -> CommitMode {
        self.commit
    }

    /// The (DW, DTS) schedules executing this trainer's sweeps.
    pub fn schedules(&self) -> (&Schedule, &Schedule) {
        (&self.word.schedule, &self.stamp.schedule)
    }

    /// One sweep: `P` epochs of (word diagonal, then timestamp diagonal).
    /// Returns (word stats, stamp stats).
    ///
    /// Both phases dispatch through the executor selected by `mode`
    /// (sharing one persistent pool in `Pooled` mode), with their
    /// phase-total snapshots double-buffered instead of cloned per epoch.
    pub fn sweep(&mut self, mode: ExecMode) -> (SweepStats, SweepStats) {
        // Detach the engine cache so the executor and `self` can be
        // borrowed mutably at once (see the matching swap in
        // `ParallelLda::sweep`); the placeholder builds nothing.
        let mut engines = std::mem::replace(&mut self.engines, EngineCache::new(0));
        let stats = self.sweep_with(engines.get(mode));
        self.engines = engines;
        stats
    }

    /// [`Self::sweep`] against an explicit [`Executor`] — the seam that
    /// lets `crate::dist::DistExec` drive both BoT phases over remote
    /// workers through the unchanged epoch loops.
    pub fn sweep_with(&mut self, exec: &mut dyn Executor) -> (SweepStats, SweepStats) {
        let sweep_no = self.sweeps_done;
        let steal = self.balance.is_steal();
        let mut wstats = SweepStats {
            workers: self.word.schedule.workers,
            ..SweepStats::default()
        };
        let mut sstats = SweepStats {
            workers: self.stamp.schedule.workers,
            ..SweepStats::default()
        };
        // Phase seconds are accumulated in the registry (word phase
        // under `Family::Word`, timestamp under `Family::Stamp`); the
        // sweep snapshots the accounts and reports its increments as
        // the two `SweepStats` below.
        let phases0 = self.metrics.phase_snapshot();
        let sweep_t0 = self.tracer.as_deref().map(Tracer::now);
        // Spill write-backs during this sweep carry the sweep count they
        // complete (see `ShardedBlocks::set_stamp`).
        self.word.shards.set_stamp(sweep_no as u64 + 1);
        self.stamp.shards.set_stamp(sweep_no as u64 + 1);
        // Fault-tolerance telemetry: IO retries are attributed per phase
        // store here; task retries are sliced per epoch inside the epoch
        // loops (the engines are shared by the phases).
        let word_io0 = self.word.shards.io_retries();
        let stamp_io0 = self.stamp.shards.io_retries();

        let update_started = Instant::now();
        self.word_snapshot.copy_from_slice(&self.counts.topic_words);
        self.stamp_snapshot
            .copy_from_slice(&self.counts.topic_stamps);
        self.metrics
            .add_phase(Family::Word, MetricPhase::Update, update_started.elapsed());

        if self.commit == CommitMode::Ticketed {
            self.ticketed_epochs(exec, &mut wstats, &mut sstats, sweep_no, steal);
        } else {
            self.barrier_epochs(exec, &mut wstats, &mut sstats, sweep_no, steal);
        }
        self.sweeps_done += 1;
        wstats.io_retries = self.word.shards.io_retries() - word_io0;
        sstats.io_retries = self.stamp.shards.io_retries() - stamp_io0;
        // Each phase folds its own telemetry every sweep (so a later
        // switch to `Adaptive` repacks from warm measurements) and,
        // under `Adaptive`, repacks its own schedule — the DW and DTS
        // grids balance independently.
        let update_started = Instant::now();
        self.word.estimator.observe_sweep(&self.word.costs, &wstats.task_nanos);
        self.stamp.estimator.observe_sweep(&self.stamp.costs, &sstats.task_nanos);
        if !steal {
            // Per-worker speed telemetry (measured vs predicted busy
            // time) for heterogeneity-aware re-packing (meaningless
            // under stealing — assignments are hints there); each phase
            // learns against its own schedule.
            for (phase, stats) in [(&mut self.word, &wstats), (&mut self.stamp, &sstats)] {
                let predicted = phase
                    .estimator
                    .predicted_worker_loads(&phase.schedule, &phase.costs);
                phase.estimator.observe_workers(&predicted, &stats.worker_nanos);
            }
        }
        if self.balance == BalanceMode::Adaptive {
            self.word.estimator.repack(&mut self.word.schedule, &self.word.costs);
            self.stamp.estimator.repack(&mut self.stamp.schedule, &self.stamp.costs);
        }
        let dt = update_started.elapsed().as_secs_f64() / 2.0;
        self.metrics
            .add_phase_secs(Family::Word, MetricPhase::Update, dt);
        self.metrics
            .add_phase_secs(Family::Stamp, MetricPhase::Update, dt);

        // Both `SweepStats` second-buckets are views over the registry:
        // this sweep's increments of each family's phase accounts.
        let m = &self.metrics;
        for (family, stats) in [(Family::Word, &mut wstats), (Family::Stamp, &mut sstats)] {
            stats.sample_secs = m.delta_secs(&phases0, family, MetricPhase::Sample);
            stats.barrier_secs = m.delta_secs(&phases0, family, MetricPhase::Barrier);
            stats.update_secs = m.delta_secs(&phases0, family, MetricPhase::Update);
            stats.commit_secs = m.delta_secs(&phases0, family, MetricPhase::Commit);
            stats.runahead_secs = m.delta_secs(&phases0, family, MetricPhase::Runahead);
            stats.io_load_secs = m.delta_secs(&phases0, family, MetricPhase::SpillLoad);
            stats.io_write_secs = m.delta_secs(&phases0, family, MetricPhase::SpillWrite);
            m.task_retries.add(stats.task_retries);
            m.io_retries.add(stats.io_retries);
            m.tasks
                .add(stats.task_nanos.iter().map(|v| v.len() as u64).sum());
            for &ns in stats.task_nanos.iter().flatten() {
                m.task_ns.observe(ns);
            }
            m.observe_eta(family, stats.busy_total_nanos(), stats.crit_nanos());
        }
        m.sweeps.inc();
        let resident =
            self.word.shards.resident_bytes() + self.stamp.shards.resident_bytes();
        m.resident_bytes.set(resident);
        m.peak_resident_bytes.set_max(
            self.word.shards.peak_resident_bytes() + self.stamp.shards.peak_resident_bytes(),
        );

        if let Some(tr) = self.tracer.as_deref() {
            let t0 = sweep_t0.unwrap_or(0);
            tr.emit(Event {
                lane: tr.coord_lane(),
                sweep: sweep_no as u32,
                t0_ns: t0,
                dur_ns: tr.now().saturating_sub(t0),
                ..Event::of(EventKind::Sweep)
            });
            for (family, stats) in [(Family::Word, &wstats), (Family::Stamp, &sstats)] {
                if stats.io_retries > 0 {
                    tr.emit(Event {
                        family: family as u8,
                        lane: tr.io_lane(),
                        sweep: sweep_no as u32,
                        t0_ns: tr.now(),
                        arg: stats.io_retries,
                        ..Event::of(EventKind::IoRetry)
                    });
                }
            }
            tr.drain();
        }
        // Debug builds audit the full two-matrix invariant per sweep so
        // kernel count-delta bugs fail at the offending sweep (see the
        // matching check in `scheduler::exec::ParallelLda::sweep`). The
        // audit needs every block in RAM, so spill-mode sweeps skip it
        // (the spill ≡ in-core matrix tests cover that path).
        #[cfg(debug_assertions)]
        if self.word.shards.fully_resident() && self.stamp.shards.fully_resident() {
            let words = self.word.shards.resident_blocks();
            let stamps = self.stamp.shards.resident_blocks();
            if let Err(e) = self.counts.check_consistency(&words, &stamps) {
                panic!(
                    "kernel {} corrupted BoT counts on sweep {sweep_no}: {e}",
                    self.kernel.name()
                );
            }
        }
        (wstats, sstats)
    }

    /// The classic scatter → sample → gather loop: each phase-epoch ends
    /// with a full [`merge_deltas`] barrier (fold every delta, republish
    /// the phase snapshot) before anything else proceeds.
    fn barrier_epochs(
        &mut self,
        exec: &mut dyn Executor,
        wstats: &mut SweepStats,
        sstats: &mut SweepStats,
        sweep_no: usize,
        steal: bool,
    ) {
        let p = self.p;
        let k = self.h.k;
        let mut task_retries_prev = exec.retries();
        for l in 0..p {
            // ---- word phase on DW diagonal l ----
            {
                // Out-of-core: land this diagonal, then overlap the
                // *timestamp* phase's diagonal-l load with the word
                // sampling below (the phases alternate, so the prefetch
                // chain is word l → stamp l → word l+1 → ...).
                let load_secs = self
                    .word
                    .shards
                    .acquire(l)
                    .expect("out-of-core: loading a DW diagonal failed");
                self.metrics
                    .add_phase_secs(Family::Word, MetricPhase::SpillLoad, load_secs);
                self.stamp.shards.prefetch(l);
                let started = Instant::now();
                let (diag, ids) = self.word.shards.diag_parts(l);
                let ep = &self.word.schedule.epochs[l];
                wstats
                    .epoch_max_tokens
                    .push(ep.max_assigned(|i| diag[i].len() as u64));
                wstats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
                let n = diag.len();
                let spec = EpochSpec {
                    doc: SharedRows::new(&mut self.counts.doc_topic, k),
                    emit: SharedRows::new(&mut self.counts.word_topic, k),
                    snapshot: &self.word_snapshot,
                    h: self.h.word_hyper(),
                    seed: self.seed ^ BOT_WORD_SALT,
                    sweep: sweep_no,
                    kernel: self.kernel,
                    obs: TaskObs {
                        trace: self.tracer.as_deref(),
                        epoch: l as u32,
                        family: Family::Word as u8,
                    },
                };
                let tasks = EpochTasks {
                    blocks: diag,
                    ids,
                    assign: &ep.assign,
                    nanos: &mut self.task_nanos[..n],
                    worker_nanos: &mut self.worker_nanos,
                    steal,
                };
                exec.run_epoch(&spec, tasks, &mut self.deltas[..n]);
                self.metrics
                    .add_phase(Family::Word, MetricPhase::Sample, started.elapsed());
                let r = exec.retries();
                wstats.task_retries += r - task_retries_prev;
                task_retries_prev = r;
                wstats.task_nanos.push(self.task_nanos[..n].to_vec());
                wstats.worker_nanos.push(self.worker_nanos.clone());
                let barrier_started = Instant::now();
                merge_deltas(
                    &mut self.counts.topic_words,
                    &mut self.word_snapshot,
                    &self.deltas[..n],
                );
                self.metrics
                    .add_phase(Family::Word, MetricPhase::Barrier, barrier_started.elapsed());
                wstats.epoch_secs.push(started.elapsed().as_secs_f64());
                let write_secs = self
                    .word
                    .shards
                    .release(l)
                    .expect("out-of-core: writing a DW diagonal back failed");
                self.metrics
                    .add_phase_secs(Family::Word, MetricPhase::SpillWrite, write_secs);
            }

            // ---- timestamp phase on DTS diagonal l ----
            {
                let load_secs = self
                    .stamp
                    .shards
                    .acquire(l)
                    .expect("out-of-core: loading a DTS diagonal failed");
                self.metrics
                    .add_phase_secs(Family::Stamp, MetricPhase::SpillLoad, load_secs);
                // Overlap the next word diagonal's load (the word phase
                // just wrote diagonal l back, so even P = 1 reads fresh
                // state for the next sweep).
                self.word.shards.prefetch((l + 1) % p);
                let started = Instant::now();
                let (diag, ids) = self.stamp.shards.diag_parts(l);
                let ep = &self.stamp.schedule.epochs[l];
                sstats
                    .epoch_max_tokens
                    .push(ep.max_assigned(|i| diag[i].len() as u64));
                sstats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
                let n = diag.len();
                let spec = EpochSpec {
                    doc: SharedRows::new(&mut self.counts.doc_topic, k),
                    emit: SharedRows::new(&mut self.counts.stamp_topic, k),
                    snapshot: &self.stamp_snapshot,
                    h: self.h.stamp_hyper(),
                    seed: self.seed ^ BOT_STAMP_SALT,
                    sweep: sweep_no,
                    kernel: self.kernel,
                    obs: TaskObs {
                        trace: self.tracer.as_deref(),
                        epoch: l as u32,
                        family: Family::Stamp as u8,
                    },
                };
                let tasks = EpochTasks {
                    blocks: diag,
                    ids,
                    assign: &ep.assign,
                    nanos: &mut self.task_nanos[..n],
                    worker_nanos: &mut self.worker_nanos,
                    steal,
                };
                exec.run_epoch(&spec, tasks, &mut self.deltas[..n]);
                self.metrics
                    .add_phase(Family::Stamp, MetricPhase::Sample, started.elapsed());
                let r = exec.retries();
                sstats.task_retries += r - task_retries_prev;
                task_retries_prev = r;
                sstats.task_nanos.push(self.task_nanos[..n].to_vec());
                sstats.worker_nanos.push(self.worker_nanos.clone());
                let barrier_started = Instant::now();
                merge_deltas(
                    &mut self.counts.topic_stamps,
                    &mut self.stamp_snapshot,
                    &self.deltas[..n],
                );
                self.metrics
                    .add_phase(Family::Stamp, MetricPhase::Barrier, barrier_started.elapsed());
                sstats.epoch_secs.push(started.elapsed().as_secs_f64());
                let write_secs = self
                    .stamp
                    .shards
                    .release(l)
                    .expect("out-of-core: writing a DTS diagonal back failed");
                self.metrics
                    .add_phase_secs(Family::Stamp, MetricPhase::SpillWrite, write_secs);
            }
        }
    }

    /// The ticketed pipeline (see `docs/executor.md`, § "Ticketed
    /// commit"): tasks carry monotonically increasing tickets and a
    /// committer folds each delta into the phase totals in strict ticket
    /// order while later tickets are still sampling. Each phase-epoch's
    /// overlap hook drives the *other* phase's shard IO — writing its
    /// finished diagonal back and prefetching its next one — so the
    /// word l → stamp l → word l+1 chain hides spill traffic behind
    /// sampling instead of serializing it at the barrier. The phase
    /// snapshot is republished only after an epoch drains (an O(K) copy,
    /// the residual "barrier" bucket); workers always sample against the
    /// same epoch-start snapshot the barrier protocol uses, so results
    /// are bit-identical.
    fn ticketed_epochs(
        &mut self,
        exec: &mut dyn Executor,
        wstats: &mut SweepStats,
        sstats: &mut SweepStats,
        sweep_no: usize,
        steal: bool,
    ) {
        let p = self.p;
        let k = self.h.k;
        let mut task_retries_prev = exec.retries();
        for l in 0..p {
            // ---- word phase on DW diagonal l ----
            {
                let load_secs = self
                    .word
                    .shards
                    .acquire(l)
                    .expect("out-of-core: loading a DW diagonal failed");
                self.metrics
                    .add_phase_secs(Family::Word, MetricPhase::SpillLoad, load_secs);
                let started = Instant::now();
                let (diag, ids) = self.word.shards.diag_parts(l);
                let ep = &self.word.schedule.epochs[l];
                wstats
                    .epoch_max_tokens
                    .push(ep.max_assigned(|i| diag[i].len() as u64));
                wstats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
                let n = diag.len();
                let spec = EpochSpec {
                    doc: SharedRows::new(&mut self.counts.doc_topic, k),
                    emit: SharedRows::new(&mut self.counts.word_topic, k),
                    snapshot: &self.word_snapshot,
                    h: self.h.word_hyper(),
                    seed: self.seed ^ BOT_WORD_SALT,
                    sweep: sweep_no,
                    kernel: self.kernel,
                    obs: TaskObs {
                        trace: self.tracer.as_deref(),
                        epoch: l as u32,
                        family: Family::Word as u8,
                    },
                };
                let tasks = EpochTasks {
                    blocks: diag,
                    ids,
                    assign: &ep.assign,
                    nanos: &mut self.task_nanos[..n],
                    worker_nanos: &mut self.worker_nanos,
                    steal,
                };
                let stamp_shards = &mut self.stamp.shards;
                let mut stamp_io_write = 0.0f64;
                // Once the word tasks are dispatched this epoch's IO
                // slot belongs to the *timestamp* store: write its
                // previous diagonal back (release-before-prefetch keeps
                // the DTS budget seeing at most two diagonals), then
                // pull in diagonal l for the timestamp epoch below.
                let mut overlap = || {
                    if l > 0 {
                        stamp_io_write += stamp_shards
                            .release(l - 1)
                            .expect("out-of-core: writing a DTS diagonal back failed");
                    }
                    stamp_shards.prefetch(l);
                };
                let topic_words = &mut self.counts.topic_words;
                let tr_commit = self.tracer.as_deref();
                let mut runahead = 0.0f64;
                let mut blocking = 0.0f64;
                let mut commit = |t: usize, delta: &[i64], in_flight: usize| {
                    let fold_started = Instant::now();
                    commit_delta(topic_words, delta);
                    let secs = fold_started.elapsed().as_secs_f64();
                    if in_flight > 0 {
                        runahead += secs;
                    } else {
                        blocking += secs;
                    }
                    if let Some(tr) = tr_commit {
                        let dur = (secs * 1e9) as u64;
                        tr.emit(Event {
                            family: Family::Word as u8,
                            lane: tr.coord_lane(),
                            sweep: sweep_no as u32,
                            epoch: l as u32,
                            ticket: t as u32,
                            t0_ns: tr.now().saturating_sub(dur),
                            dur_ns: dur,
                            arg: in_flight as u64,
                            ..Event::of(EventKind::Commit)
                        });
                    }
                };
                exec.run_epoch_ticketed(
                    &spec,
                    tasks,
                    &mut self.deltas[..n],
                    &mut overlap,
                    &mut commit,
                );
                let m = &self.metrics;
                m.add_phase(Family::Word, MetricPhase::Sample, started.elapsed());
                m.add_phase_secs(Family::Stamp, MetricPhase::SpillWrite, stamp_io_write);
                m.add_phase_secs(Family::Word, MetricPhase::Runahead, runahead);
                m.add_phase_secs(Family::Word, MetricPhase::Commit, blocking);
                let r = exec.retries();
                wstats.task_retries += r - task_retries_prev;
                task_retries_prev = r;
                wstats.task_nanos.push(self.task_nanos[..n].to_vec());
                wstats.worker_nanos.push(self.worker_nanos.clone());
                let barrier_started = Instant::now();
                self.word_snapshot.copy_from_slice(&self.counts.topic_words);
                self.metrics
                    .add_phase(Family::Word, MetricPhase::Barrier, barrier_started.elapsed());
                wstats.epoch_secs.push(started.elapsed().as_secs_f64());
            }

            // ---- timestamp phase on DTS diagonal l ----
            {
                let load_secs = self
                    .stamp
                    .shards
                    .acquire(l)
                    .expect("out-of-core: loading a DTS diagonal failed");
                self.metrics
                    .add_phase_secs(Family::Stamp, MetricPhase::SpillLoad, load_secs);
                let started = Instant::now();
                let (diag, ids) = self.stamp.shards.diag_parts(l);
                let ep = &self.stamp.schedule.epochs[l];
                sstats
                    .epoch_max_tokens
                    .push(ep.max_assigned(|i| diag[i].len() as u64));
                sstats.total_tokens += diag.iter().map(|b| b.len() as u64).sum::<u64>();
                let n = diag.len();
                let spec = EpochSpec {
                    doc: SharedRows::new(&mut self.counts.doc_topic, k),
                    emit: SharedRows::new(&mut self.counts.stamp_topic, k),
                    snapshot: &self.stamp_snapshot,
                    h: self.h.stamp_hyper(),
                    seed: self.seed ^ BOT_STAMP_SALT,
                    sweep: sweep_no,
                    kernel: self.kernel,
                    obs: TaskObs {
                        trace: self.tracer.as_deref(),
                        epoch: l as u32,
                        family: Family::Stamp as u8,
                    },
                };
                let tasks = EpochTasks {
                    blocks: diag,
                    ids,
                    assign: &ep.assign,
                    nanos: &mut self.task_nanos[..n],
                    worker_nanos: &mut self.worker_nanos,
                    steal,
                };
                let word_shards = &mut self.word.shards;
                let mut word_io_write = 0.0f64;
                // The word epoch for this diagonal has fully committed,
                // so its blocks are written back while the timestamp
                // tasks sample; the write-back precedes the prefetch so
                // even P = 1 reads fresh state for the next sweep
                // (matching the barrier path's release/prefetch order).
                let mut overlap = || {
                    word_io_write += word_shards
                        .release(l)
                        .expect("out-of-core: writing a DW diagonal back failed");
                    word_shards.prefetch((l + 1) % p);
                };
                let topic_stamps = &mut self.counts.topic_stamps;
                let tr_commit = self.tracer.as_deref();
                let mut runahead = 0.0f64;
                let mut blocking = 0.0f64;
                let mut commit = |t: usize, delta: &[i64], in_flight: usize| {
                    let fold_started = Instant::now();
                    commit_delta(topic_stamps, delta);
                    let secs = fold_started.elapsed().as_secs_f64();
                    if in_flight > 0 {
                        runahead += secs;
                    } else {
                        blocking += secs;
                    }
                    if let Some(tr) = tr_commit {
                        let dur = (secs * 1e9) as u64;
                        tr.emit(Event {
                            family: Family::Stamp as u8,
                            lane: tr.coord_lane(),
                            sweep: sweep_no as u32,
                            epoch: l as u32,
                            ticket: t as u32,
                            t0_ns: tr.now().saturating_sub(dur),
                            dur_ns: dur,
                            arg: in_flight as u64,
                            ..Event::of(EventKind::Commit)
                        });
                    }
                };
                exec.run_epoch_ticketed(
                    &spec,
                    tasks,
                    &mut self.deltas[..n],
                    &mut overlap,
                    &mut commit,
                );
                let m = &self.metrics;
                m.add_phase(Family::Stamp, MetricPhase::Sample, started.elapsed());
                m.add_phase_secs(Family::Word, MetricPhase::SpillWrite, word_io_write);
                m.add_phase_secs(Family::Stamp, MetricPhase::Runahead, runahead);
                m.add_phase_secs(Family::Stamp, MetricPhase::Commit, blocking);
                let r = exec.retries();
                sstats.task_retries += r - task_retries_prev;
                task_retries_prev = r;
                sstats.task_nanos.push(self.task_nanos[..n].to_vec());
                sstats.worker_nanos.push(self.worker_nanos.clone());
                let barrier_started = Instant::now();
                self.stamp_snapshot
                    .copy_from_slice(&self.counts.topic_stamps);
                self.metrics
                    .add_phase(Family::Stamp, MetricPhase::Barrier, barrier_started.elapsed());
                sstats.epoch_secs.push(started.elapsed().as_secs_f64());
            }
        }
        // The final timestamp diagonal has no following word epoch whose
        // overlap would write it back; settle it here (in-core: no-op).
        let write_secs = self
            .stamp
            .shards
            .release(p - 1)
            .expect("out-of-core: writing a DTS diagonal back failed");
        self.metrics
            .add_phase_secs(Family::Stamp, MetricPhase::SpillWrite, write_secs);
    }

    /// The persistent worker pool, if any `Pooled`-mode sweep has run on
    /// this trainer.
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.engines.pool()
    }

    pub fn train(
        &mut self,
        tc: &TimestampedCorpus,
        iters: usize,
        eval_every: usize,
        mode: ExecMode,
    ) -> Vec<(usize, f64)> {
        let mut curve = Vec::new();
        for it in 1..=iters {
            self.sweep(mode);
            if eval_every > 0 && (it % eval_every == 0 || it == iters) {
                curve.push((it, self.perplexity(tc)));
            }
        }
        curve
    }

    /// Table IV metric: word perplexity.
    pub fn perplexity(&self, tc: &TimestampedCorpus) -> f64 {
        super::perplexity_words(&tc.bow, &self.counts, &self.h)
    }

    /// All resident DW blocks (the whole matrix in-core).
    pub fn word_blocks_flat(&self) -> Vec<&TokenBlock> {
        self.word.shards.resident_blocks()
    }

    /// All resident DTS blocks (the whole matrix in-core).
    pub fn stamp_blocks_flat(&self) -> Vec<&TokenBlock> {
        self.stamp.shards.resident_blocks()
    }

    /// The residency policy both phases run under, as configured (the
    /// spill budget is split evenly between the phases internally).
    pub fn residency(&self) -> Residency {
        self.residency
    }

    /// Combined high-water mark of resident token bytes across both
    /// phases (a safe upper bound on the true combined peak: per-phase
    /// peaks may not coincide).
    pub fn peak_resident_bytes(&self) -> u64 {
        self.word.shards.peak_resident_bytes() + self.stamp.shards.peak_resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate_timestamped, Profile, TimeProfile};
    use crate::partition::{partition, Algorithm};

    fn tiny_tc(seed: u64) -> TimestampedCorpus {
        let mut p = Profile::tiny();
        p.time = Some(TimeProfile {
            first_year: 2000,
            last_year: 2009,
            growth: 0.1,
            stamps_per_doc: 4,
        });
        generate_timestamped(&p, seed)
    }

    fn setup(p: usize, seed: u64) -> (TimestampedCorpus, ParallelBot) {
        let tc = tiny_tc(seed);
        let plan_dw = partition(&tc.bow, p, Algorithm::A3 { restarts: 3 }, seed);
        let plan_dts = partition(&tc.dts, p, Algorithm::A3 { restarts: 3 }, seed + 1);
        let h = super::super::serial::BotHyper::new(
            8,
            0.5,
            0.1,
            0.1,
            tc.bow.num_words(),
            tc.num_stamps,
        );
        let bot = ParallelBot::init(&tc, &plan_dw, &plan_dts, h, seed);
        (tc, bot)
    }

    fn setup_scheduled(
        grid: usize,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
    ) -> (TimestampedCorpus, ParallelBot) {
        let tc = tiny_tc(seed);
        let plan_dw = partition(&tc.bow, grid, Algorithm::A3 { restarts: 3 }, seed);
        let plan_dts = partition(&tc.dts, grid, Algorithm::A3 { restarts: 3 }, seed + 1);
        let h = super::super::serial::BotHyper::new(
            8,
            0.5,
            0.1,
            0.1,
            tc.bow.num_words(),
            tc.num_stamps,
        );
        let bot = ParallelBot::init_scheduled(&tc, &plan_dw, &plan_dts, h, seed, kind, workers);
        (tc, bot)
    }

    #[test]
    fn init_covers_both_matrices() {
        let (tc, bot) = setup(3, 61);
        assert_eq!(bot.counts.total(), tc.total_tokens());
        assert!(bot
            .counts
            .check_consistency(&bot.word_blocks_flat(), &bot.stamp_blocks_flat())
            .is_ok());
    }

    #[test]
    fn sweep_preserves_invariants() {
        let (tc, mut bot) = setup(3, 62);
        for _ in 0..3 {
            let (ws, ss) = bot.sweep(ExecMode::Sequential);
            assert_eq!(ws.total_tokens, tc.bow.num_tokens());
            assert_eq!(ss.total_tokens, tc.dts.num_tokens());
        }
        assert_eq!(bot.counts.total(), tc.total_tokens());
        assert!(bot
            .counts
            .check_consistency(&bot.word_blocks_flat(), &bot.stamp_blocks_flat())
            .is_ok());
    }

    #[test]
    fn threaded_equals_sequential() {
        let (_tc, mut a) = setup(4, 63);
        let (_tc2, mut b) = setup(4, 63);
        for _ in 0..2 {
            a.sweep(ExecMode::Threaded);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.stamp_topic, b.counts.stamp_topic);
    }

    #[test]
    fn pooled_equals_sequential() {
        let (_tc, mut a) = setup(4, 65);
        let (_tc2, mut b) = setup(4, 65);
        for _ in 0..3 {
            a.sweep(ExecMode::Pooled);
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.stamp_topic, b.counts.stamp_topic);
        assert_eq!(a.counts.topic_words, b.counts.topic_words);
        assert_eq!(a.counts.topic_stamps, b.counts.topic_stamps);
    }

    #[test]
    fn packed_pooled_bot_matches_sequential_across_worker_counts() {
        // Cross-schedule determinism for both phases: grid-4 plans packed
        // onto W ∈ {1, 2, 4} and run Pooled equal the diagonal
        // Sequential oracle bit for bit.
        let (_tc, mut oracle) = setup(4, 67);
        for _ in 0..2 {
            oracle.sweep(ExecMode::Sequential);
        }
        for workers in [1usize, 2, 4] {
            let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
            let (_t, mut bot) = setup_scheduled(4, 67, kind, workers);
            assert_eq!(bot.workers(), workers);
            for _ in 0..2 {
                bot.sweep(ExecMode::Pooled);
            }
            assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "W={workers}");
            assert_eq!(bot.counts.word_topic, oracle.counts.word_topic, "W={workers}");
            assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic, "W={workers}");
            assert_eq!(bot.counts.topic_words, oracle.counts.topic_words, "W={workers}");
            assert_eq!(bot.counts.topic_stamps, oracle.counts.topic_stamps, "W={workers}");
        }
    }

    #[test]
    fn every_kernel_is_bit_identical_across_modes_and_workers_bot() {
        // Kernel determinism over both phases: Sequential diagonal is
        // the oracle; packed Pooled at W ∈ {1, 2, 4} must match bit for
        // bit for each kernel (the timestamp phase exercises the folded
        // γ/S·γ hyperparameters).
        for kernel in KernelKind::all() {
            let (_tc, mut oracle) = setup(4, 81);
            oracle.set_kernel(kernel);
            for _ in 0..2 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                let (_t, mut bot) = setup_scheduled(4, 81, kind, workers);
                bot.set_kernel(kernel);
                assert_eq!(bot.kernel(), kernel);
                for _ in 0..2 {
                    bot.sweep(ExecMode::Pooled);
                }
                assert_eq!(
                    bot.counts.doc_topic,
                    oracle.counts.doc_topic,
                    "{kernel:?} W={workers}"
                );
                assert_eq!(
                    bot.counts.word_topic,
                    oracle.counts.word_topic,
                    "{kernel:?} W={workers}"
                );
                assert_eq!(
                    bot.counts.stamp_topic,
                    oracle.counts.stamp_topic,
                    "{kernel:?} W={workers}"
                );
                assert_eq!(
                    bot.counts.topic_words,
                    oracle.counts.topic_words,
                    "{kernel:?} W={workers}"
                );
                assert_eq!(
                    bot.counts.topic_stamps,
                    oracle.counts.topic_stamps,
                    "{kernel:?} W={workers}"
                );
            }
        }
    }

    #[test]
    fn stealing_bot_is_bit_identical_across_kernels_and_workers() {
        // The stealing acceptance for BoT: both phases, every kernel,
        // W ∈ {1, 2, 4}, Pooled stealing vs the static Sequential
        // oracle — bit-identical counts.
        for kernel in KernelKind::all() {
            let (_tc, mut oracle) = setup(4, 83);
            oracle.set_kernel(kernel);
            for _ in 0..2 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                let (_t, mut bot) = setup_scheduled(4, 83, kind, workers);
                bot.set_kernel(kernel);
                bot.set_balance(BalanceMode::Steal);
                assert_eq!(bot.balance(), BalanceMode::Steal);
                for _ in 0..2 {
                    bot.sweep(ExecMode::Pooled);
                }
                assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "{kernel:?} W={workers}");
                assert_eq!(
                    bot.counts.word_topic,
                    oracle.counts.word_topic,
                    "{kernel:?} W={workers}"
                );
                assert_eq!(
                    bot.counts.stamp_topic,
                    oracle.counts.stamp_topic,
                    "{kernel:?} W={workers}"
                );
                assert_eq!(
                    bot.counts.topic_words,
                    oracle.counts.topic_words,
                    "{kernel:?} W={workers}"
                );
                assert_eq!(
                    bot.counts.topic_stamps,
                    oracle.counts.topic_stamps,
                    "{kernel:?} W={workers}"
                );
            }
        }
    }

    #[test]
    fn stealing_bot_matches_sequential_on_random_schedules() {
        // Property form over random (g, W) and kernels, both exec
        // parallel modes, both phases.
        crate::testing::prop::check("bot-steal-bit-identical", 0xB07_57EA1, 4, |rng| {
            let w = [1usize, 2, 4][rng.gen_range(3)];
            let g = 1 + rng.gen_range(2);
            let p = g * w;
            let seed = rng.next_u64() | 1;
            let tc = tiny_tc(seed);
            let plan_dw = partition(&tc.bow, p, Algorithm::A3 { restarts: 1 }, seed);
            let plan_dts = partition(&tc.dts, p, Algorithm::A3 { restarts: 1 }, seed + 1);
            let h = super::super::serial::BotHyper::new(
                4,
                0.5,
                0.1,
                0.1,
                tc.bow.num_words(),
                tc.num_stamps,
            );
            let kernel = KernelKind::all()[rng.gen_range(3)];
            let kind = ScheduleKind::Packed { grid_factor: g };
            let mut oracle =
                ParallelBot::init_scheduled(&tc, &plan_dw, &plan_dts, h, seed, kind, w);
            oracle.set_kernel(kernel);
            oracle.sweep(ExecMode::Sequential);
            for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                let mut bot =
                    ParallelBot::init_scheduled(&tc, &plan_dw, &plan_dts, h, seed, kind, w);
                bot.set_kernel(kernel);
                bot.set_balance(BalanceMode::Steal);
                bot.sweep(mode);
                assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "{kernel:?} {mode:?}");
                assert_eq!(
                    bot.counts.word_topic,
                    oracle.counts.word_topic,
                    "{kernel:?} {mode:?}"
                );
                assert_eq!(
                    bot.counts.stamp_topic,
                    oracle.counts.stamp_topic,
                    "{kernel:?} {mode:?}"
                );
            }
        });
    }

    #[test]
    fn ticketed_bot_is_bit_identical_across_kernels_modes_and_workers() {
        // The ticketed-commit acceptance for BoT: both phases pipeline
        // their in-order commits, and every kernel × mode × W matches
        // the barrier Sequential oracle bit for bit.
        for kernel in KernelKind::all() {
            let (_tc, mut oracle) = setup(4, 141);
            oracle.set_kernel(kernel);
            for _ in 0..2 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                for mode in [ExecMode::Sequential, ExecMode::Pooled] {
                    let (_t, mut bot) = setup_scheduled(4, 141, kind, workers);
                    bot.set_kernel(kernel);
                    bot.set_commit(CommitMode::Ticketed);
                    assert_eq!(bot.commit(), CommitMode::Ticketed);
                    for _ in 0..2 {
                        bot.sweep(mode);
                    }
                    let tag = format!("{kernel:?} {mode:?} W={workers}");
                    assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                    assert_eq!(bot.counts.word_topic, oracle.counts.word_topic, "{tag}");
                    assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic, "{tag}");
                    assert_eq!(bot.counts.topic_words, oracle.counts.topic_words, "{tag}");
                    assert_eq!(bot.counts.topic_stamps, oracle.counts.topic_stamps, "{tag}");
                }
            }
        }
    }

    #[test]
    fn ticketed_bot_spill_steal_and_adaptive_match_barrier() {
        // Ticketed commit composes with spilling, stealing, and
        // adaptive re-packing in both phases: the overlap hooks carry
        // the cross-phase IO chain and results stay bit-identical.
        let spill = Residency::Spill { budget_bytes: 0 };
        let (_tc, mut oracle) = setup(4, 142);
        for _ in 0..2 {
            oracle.sweep(ExecMode::Sequential);
        }
        for (balance, residency) in [
            (BalanceMode::Static, spill),
            (BalanceMode::Steal, Residency::InCore),
            (BalanceMode::Steal, spill),
            (BalanceMode::Adaptive, Residency::InCore),
        ] {
            for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                let kind = ScheduleKind::Packed { grid_factor: 2 };
                let (_t, mut bot) = setup_resident(4, 142, kind, 2, residency);
                bot.set_commit(CommitMode::Ticketed);
                bot.set_balance(balance);
                for _ in 0..2 {
                    bot.sweep(mode);
                }
                let tag = format!("{balance:?} {residency:?} {mode:?}");
                assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                assert_eq!(bot.counts.word_topic, oracle.counts.word_topic, "{tag}");
                assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic, "{tag}");
                assert_eq!(bot.counts.topic_words, oracle.counts.topic_words, "{tag}");
                assert_eq!(bot.counts.topic_stamps, oracle.counts.topic_stamps, "{tag}");
            }
        }
    }

    #[test]
    fn ticketed_bot_switches_modes_and_fills_commit_buckets() {
        let (_tc, mut oracle) = setup(4, 143);
        for _ in 0..3 {
            oracle.sweep(ExecMode::Sequential);
        }
        let (_t, mut bot) = setup_scheduled(4, 143, ScheduleKind::Packed { grid_factor: 2 }, 2);
        let (wb, sb) = bot.sweep(ExecMode::Pooled);
        for stats in [&wb, &sb] {
            assert_eq!(stats.runahead_secs, 0.0, "barrier meters no early folds");
            assert_eq!(stats.commit_secs, 0.0);
        }
        bot.set_commit(CommitMode::Ticketed);
        let (wt, st) = bot.sweep(ExecMode::Pooled);
        for stats in [&wt, &st] {
            assert!(
                stats.runahead_secs + stats.commit_secs > 0.0,
                "ticketed folds are metered"
            );
            assert_eq!(stats.epoch_secs.len(), 4);
        }
        bot.set_commit(CommitMode::Barrier);
        bot.sweep(ExecMode::Pooled);
        assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic);
        assert_eq!(bot.counts.word_topic, oracle.counts.word_topic);
        assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic);
    }

    #[test]
    fn ticketed_bot_matches_barrier_on_random_schedules() {
        // Property form of the ticketed acceptance: random (g, W) and
        // kernel, ticketed Threaded/Pooled vs the barrier Sequential
        // oracle over both phases.
        crate::testing::prop::check("bot-ticketed-bit-identical", 0xB07_71C4, 4, |rng| {
            let w = [1usize, 2, 4][rng.gen_range(3)];
            let g = 1 + rng.gen_range(2);
            let p = g * w;
            let seed = rng.next_u64() | 1;
            let tc = tiny_tc(seed);
            let plan_dw = partition(&tc.bow, p, Algorithm::A3 { restarts: 1 }, seed);
            let plan_dts = partition(&tc.dts, p, Algorithm::A3 { restarts: 1 }, seed + 1);
            let h = super::super::serial::BotHyper::new(
                4,
                0.5,
                0.1,
                0.1,
                tc.bow.num_words(),
                tc.num_stamps,
            );
            let kernel = KernelKind::all()[rng.gen_range(3)];
            let kind = ScheduleKind::Packed { grid_factor: g };
            let mut oracle =
                ParallelBot::init_scheduled(&tc, &plan_dw, &plan_dts, h, seed, kind, w);
            oracle.set_kernel(kernel);
            oracle.sweep(ExecMode::Sequential);
            for mode in [ExecMode::Threaded, ExecMode::Pooled] {
                let mut bot =
                    ParallelBot::init_scheduled(&tc, &plan_dw, &plan_dts, h, seed, kind, w);
                bot.set_kernel(kernel);
                bot.set_commit(CommitMode::Ticketed);
                bot.sweep(mode);
                assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "{kernel:?} {mode:?}");
                assert_eq!(
                    bot.counts.word_topic,
                    oracle.counts.word_topic,
                    "{kernel:?} {mode:?}"
                );
                assert_eq!(
                    bot.counts.stamp_topic,
                    oracle.counts.stamp_topic,
                    "{kernel:?} {mode:?}"
                );
            }
        });
    }

    fn setup_resident(
        grid: usize,
        seed: u64,
        kind: ScheduleKind,
        workers: usize,
        residency: Residency,
    ) -> (TimestampedCorpus, ParallelBot) {
        let tc = tiny_tc(seed);
        let plan_dw = partition(&tc.bow, grid, Algorithm::A3 { restarts: 3 }, seed);
        let plan_dts = partition(&tc.dts, grid, Algorithm::A3 { restarts: 3 }, seed + 1);
        let h = super::super::serial::BotHyper::new(
            8,
            0.5,
            0.1,
            0.1,
            tc.bow.num_words(),
            tc.num_stamps,
        );
        let bot =
            ParallelBot::init_resident(&tc, &plan_dw, &plan_dts, h, seed, kind, workers, residency)
                .expect("spill init");
        (tc, bot)
    }

    #[test]
    fn spilled_bot_matches_in_core_across_kernels_modes_and_workers() {
        // The out-of-core acceptance matrix for BoT: both phases spill
        // and stream, and every kernel × mode × W combination equals the
        // in-core Sequential diagonal oracle bit for bit.
        let spill = Residency::Spill { budget_bytes: 0 };
        for kernel in KernelKind::all() {
            let (_tc, mut oracle) = setup(4, 86);
            oracle.set_kernel(kernel);
            for _ in 0..2 {
                oracle.sweep(ExecMode::Sequential);
            }
            for workers in [1usize, 2, 4] {
                let kind = ScheduleKind::Packed { grid_factor: 4 / workers };
                for mode in [ExecMode::Sequential, ExecMode::Pooled] {
                    let (_t, mut bot) = setup_resident(4, 86, kind, workers, spill);
                    assert_eq!(bot.residency(), spill);
                    bot.set_kernel(kernel);
                    for _ in 0..2 {
                        bot.sweep(mode);
                    }
                    assert_eq!(
                        bot.counts.doc_topic,
                        oracle.counts.doc_topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        bot.counts.word_topic,
                        oracle.counts.word_topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        bot.counts.stamp_topic,
                        oracle.counts.stamp_topic,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        bot.counts.topic_words,
                        oracle.counts.topic_words,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                    assert_eq!(
                        bot.counts.topic_stamps,
                        oracle.counts.topic_stamps,
                        "{kernel:?} {mode:?} W={workers}"
                    );
                }
            }
        }
    }

    #[test]
    fn spilled_bot_respects_memory_budget() {
        // Budget the phases to 3/4 of the combined matrices (the DTS
        // matrix is much smaller than DW here, and the budget is split
        // evenly, so the DW half must still fit one ~quarter-corpus
        // diagonal): peak resident bytes must honor the bound while
        // training stays bit-identical.
        let (_tc, mut in_core) = setup(4, 88);
        let corpus_bytes = in_core.peak_resident_bytes();
        for _ in 0..2 {
            in_core.sweep(ExecMode::Sequential);
        }
        let budget = corpus_bytes * 3 / 4;
        let spill = Residency::Spill { budget_bytes: budget };
        let (_t, mut bot) = setup_resident(4, 88, ScheduleKind::Diagonal, 4, spill);
        let mut io_write = 0.0;
        for _ in 0..2 {
            let (ws, ss) = bot.sweep(ExecMode::Sequential);
            io_write += ws.io_write_secs + ss.io_write_secs;
        }
        assert_eq!(bot.counts.doc_topic, in_core.counts.doc_topic);
        assert_eq!(bot.counts.word_topic, in_core.counts.word_topic);
        assert_eq!(bot.counts.stamp_topic, in_core.counts.stamp_topic);
        let peak = bot.peak_resident_bytes();
        assert!(peak > 0);
        assert!(peak <= budget, "peak {peak} exceeded budget {budget}");
        assert!(peak < corpus_bytes, "spill held less than both matrices");
        assert!(io_write > 0.0, "write-back happened in both phases");
    }

    #[test]
    fn adaptive_bot_is_bit_identical_and_both_phases_learn() {
        let (_tc, mut oracle) = setup(4, 84);
        for _ in 0..3 {
            oracle.sweep(ExecMode::Sequential);
        }
        let (_t, mut bot) = setup_scheduled(4, 84, ScheduleKind::Packed { grid_factor: 2 }, 2);
        bot.set_balance(BalanceMode::Adaptive);
        for _ in 0..3 {
            bot.sweep(ExecMode::Pooled);
        }
        assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic);
        assert_eq!(bot.counts.word_topic, oracle.counts.word_topic);
        assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic);
        assert!(bot.word.estimator.rate() > 0.0, "DW estimator learned");
        assert!(bot.stamp.estimator.rate() > 0.0, "DTS estimator learned");
    }

    #[test]
    fn bot_sweep_telemetry_covers_both_phases() {
        let (tc, mut bot) = setup_scheduled(4, 85, ScheduleKind::Packed { grid_factor: 2 }, 2);
        let (ws, ss) = bot.sweep(ExecMode::Pooled);
        for stats in [&ws, &ss] {
            assert_eq!(stats.task_nanos.len(), 4);
            assert_eq!(stats.worker_nanos.len(), 4);
            let task_total: u64 = stats.task_nanos.iter().flatten().sum();
            assert_eq!(task_total, stats.busy_total_nanos());
            let eta = stats.measured_eta();
            assert!(eta > 0.0 && eta <= 1.0 + 1e-12, "measured eta {eta}");
        }
        assert_eq!(ws.total_tokens, tc.bow.num_tokens());
        assert_eq!(ss.total_tokens, tc.dts.num_tokens());
    }

    #[test]
    fn sparse_and_alias_bot_close_to_dense() {
        // Statistical validation of the non-dense kernels on BoT: all
        // three converge to approximately the same word perplexity.
        let (tc, mut dense) = setup(4, 82);
        dense.train(&tc, 30, 0, ExecMode::Sequential);
        let pd = dense.perplexity(&tc);
        for kernel in [KernelKind::Sparse, KernelKind::Alias] {
            let (_t, mut bot) = setup(4, 82);
            bot.set_kernel(kernel);
            bot.train(&tc, 30, 0, ExecMode::Sequential);
            let pk = bot.perplexity(&tc);
            let rel = (pk - pd).abs() / pd;
            assert!(rel < 0.05, "{kernel:?}: dense {pd} vs {pk} (rel {rel})");
        }
    }

    #[test]
    fn bot_schedules_and_modes_switch_mid_training() {
        let (_tc, mut a) = setup_scheduled(4, 68, ScheduleKind::Packed { grid_factor: 2 }, 2);
        let (_tc2, mut b) = setup(4, 68);
        a.sweep(ExecMode::Pooled);
        a.set_schedule(ScheduleKind::Diagonal, 4);
        a.sweep(ExecMode::Sequential);
        a.set_schedule(ScheduleKind::Packed { grid_factor: 4 }, 1);
        a.sweep(ExecMode::Threaded);
        for _ in 0..3 {
            b.sweep(ExecMode::Sequential);
        }
        assert_eq!(a.counts.doc_topic, b.counts.doc_topic);
        assert_eq!(a.counts.word_topic, b.counts.word_topic);
        assert_eq!(a.counts.stamp_topic, b.counts.stamp_topic);
    }

    #[test]
    fn one_pool_serves_both_phases_across_sweeps() {
        let (_tc, mut bot) = setup(3, 66);
        assert!(bot.pool().is_none());
        for _ in 0..3 {
            bot.sweep(ExecMode::Pooled);
        }
        let pool = bot.pool().expect("pool created on first pooled sweep");
        assert_eq!(pool.workers(), 3, "no respawn: worker count stable at W");
        // 3 sweeps × P epochs × 2 phases, all on the same pool.
        assert_eq!(pool.epochs_run(), 3 * 3 * 2);
    }

    #[test]
    fn parallel_bot_close_to_serial_bot() {
        // Table IV in miniature: perplexities approximately equal.
        let (tc, mut par) = setup(4, 64);
        let h = par.h;
        let mut ser = super::super::serial::SerialBot::init(&tc, h, 64);
        par.train(&tc, 30, 0, ExecMode::Sequential);
        ser.train(&tc, 30, 0);
        let pp = par.perplexity(&tc);
        let ps = ser.perplexity(&tc);
        let rel = (pp - ps).abs() / ps;
        assert!(rel < 0.05, "parallel {pp} vs serial {ps} (rel {rel})");
    }

    #[test]
    fn export_and_resume_from_store_roundtrip_bot() {
        // The BoT checkpoint primitive: export both phases' blocks
        // between sweeps, rebuild a fresh trainer from the exported
        // stores (under either residency), continue — bit-identical to
        // the uninterrupted run.
        let (_tc, mut oracle) = setup(4, 89);
        for _ in 0..4 {
            oracle.sweep(ExecMode::Sequential);
        }
        let (tc, mut bot) = setup(4, 89);
        let h = bot.h;
        for _ in 0..2 {
            bot.sweep(ExecMode::Sequential);
        }
        let dw = ShardStore::create_temp("bot-dw-export").expect("create DW export store");
        let dts = ShardStore::create_temp("bot-dts-export").expect("create DTS export store");
        bot.export_blocks(&dw, &dts).expect("export");
        assert_eq!(bot.sweeps_done(), 2);
        assert_eq!(bot.seed(), 89);
        drop(bot);

        let plan_dw = partition(&tc.bow, 4, Algorithm::A3 { restarts: 3 }, 89);
        let plan_dts = partition(&tc.dts, 4, Algorithm::A3 { restarts: 3 }, 90);
        // A wrong sweep count is refused via the per-block sweep stamps.
        assert!(ParallelBot::resume_from_store(
            &tc,
            &plan_dw,
            &plan_dts,
            h,
            89,
            ScheduleKind::Diagonal,
            4,
            &dw,
            &dts,
            1,
            Residency::InCore,
        )
        .is_err());
        for residency in [Residency::InCore, Residency::Spill { budget_bytes: 0 }] {
            let mut resumed = ParallelBot::resume_from_store(
                &tc,
                &plan_dw,
                &plan_dts,
                h,
                89,
                ScheduleKind::Diagonal,
                4,
                &dw,
                &dts,
                2,
                residency,
            )
            .expect("resume from exported stores");
            assert_eq!(resumed.sweeps_done(), 2);
            for _ in 0..2 {
                resumed.sweep(ExecMode::Sequential);
            }
            assert_eq!(
                resumed.counts.doc_topic, oracle.counts.doc_topic,
                "{residency:?}: resumed run continues the chain bit-identically"
            );
            assert_eq!(resumed.counts.word_topic, oracle.counts.word_topic);
            assert_eq!(resumed.counts.stamp_topic, oracle.counts.stamp_topic);
            assert_eq!(resumed.counts.topic_words, oracle.counts.topic_words);
            assert_eq!(resumed.counts.topic_stamps, oracle.counts.topic_stamps);
        }
    }

    /// The BoT fault-tolerance acceptance matrix: one injected worker
    /// panic in each phase (and, when spilling, a transient IO error on
    /// the DW store plus a torn write-back on the DTS store) per
    /// training run, across kernels × exec modes × residency — every run
    /// must complete and match the undisturbed Sequential oracle bit for
    /// bit, with the retries attributed to the right phase's telemetry.
    #[cfg(feature = "failpoints")]
    mod fault_injection {
        use super::*;
        use crate::util::fault::{self, install, Fault, FaultKind, ANY};

        #[test]
        fn faulted_bot_training_matches_oracle_across_kernels_modes_and_residency() {
            const SEED: u64 = 0xFA17_0021;
            let spill = Residency::Spill { budget_bytes: 0 };
            for kernel in KernelKind::all() {
                let (_tc, mut oracle) = setup(4, SEED);
                oracle.set_kernel(kernel);
                for _ in 0..3 {
                    oracle.sweep(ExecMode::Sequential);
                }
                for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                    for residency in [Residency::InCore, spill] {
                        let (_t, mut bot) =
                            setup_resident(4, SEED, ScheduleKind::Diagonal, 4, residency);
                        bot.set_kernel(kernel);
                        let mut faults = vec![
                            Fault {
                                site: "task",
                                key: [SEED ^ BOT_WORD_SALT, 0, ANY],
                                kind: FaultKind::Panic,
                            },
                            Fault {
                                site: "task",
                                key: [SEED ^ BOT_STAMP_SALT, 1, ANY],
                                kind: FaultKind::Panic,
                            },
                        ];
                        let (dw_dir, dts_dir) = bot.spill_dirs();
                        if let Some(dir) = dw_dir {
                            faults.push(Fault {
                                site: "shard.read",
                                key: [fault::path_token(dir), ANY, ANY],
                                kind: FaultKind::IoError,
                            });
                        }
                        if let Some(dir) = dts_dir {
                            faults.push(Fault {
                                site: "shard.write_z",
                                key: [fault::path_token(dir), ANY, ANY],
                                kind: FaultKind::TornWrite,
                            });
                        }
                        let guard = install(faults);
                        let mut word_retries = 0u64;
                        let mut stamp_retries = 0u64;
                        let mut io_retries = 0u64;
                        for _ in 0..3 {
                            let (ws, ss) = bot.sweep(mode);
                            word_retries += ws.task_retries;
                            stamp_retries += ss.task_retries;
                            io_retries += ws.io_retries + ss.io_retries;
                        }
                        drop(guard);
                        let tag = format!("{kernel:?} {mode:?} {residency:?}");
                        assert_eq!(word_retries, 1, "{tag}: one contained DW-phase panic");
                        assert_eq!(stamp_retries, 1, "{tag}: one contained DTS-phase panic");
                        if residency == spill {
                            assert_eq!(io_retries, 2, "{tag}: torn write + IO error retried");
                        } else {
                            assert_eq!(io_retries, 0, "{tag}: in-core performs no IO");
                        }
                        assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                        assert_eq!(bot.counts.word_topic, oracle.counts.word_topic, "{tag}");
                        assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic, "{tag}");
                        assert_eq!(bot.counts.topic_words, oracle.counts.topic_words, "{tag}");
                        assert_eq!(bot.counts.topic_stamps, oracle.counts.topic_stamps, "{tag}");
                        if residency == Residency::InCore {
                            assert!(
                                bot.counts
                                    .check_consistency(
                                        &bot.word_blocks_flat(),
                                        &bot.stamp_blocks_flat()
                                    )
                                    .is_ok(),
                                "{tag}"
                            );
                        }
                    }
                }
            }
        }

        #[test]
        fn ticketed_bot_commit_faults_roll_back_and_match_oracle() {
            // The `commit` failpoint fires after a task has fully
            // sampled, so the rollback must undo a *completed* task
            // exactly in whichever phase it hit; the ticketed retry then
            // recommits bit-identically in ticket order.
            const SEED: u64 = 0xFA17_0051;
            let spill = Residency::Spill { budget_bytes: 0 };
            let (_tc, mut oracle) = setup(4, SEED);
            for _ in 0..2 {
                oracle.sweep(ExecMode::Sequential);
            }
            for mode in [ExecMode::Sequential, ExecMode::Threaded, ExecMode::Pooled] {
                for residency in [Residency::InCore, spill] {
                    let (_t, mut bot) =
                        setup_resident(4, SEED, ScheduleKind::Diagonal, 4, residency);
                    bot.set_commit(CommitMode::Ticketed);
                    let guard = install(vec![
                        Fault {
                            site: "commit",
                            key: [SEED ^ BOT_WORD_SALT, 0, ANY],
                            kind: FaultKind::Panic,
                        },
                        Fault {
                            site: "commit",
                            key: [SEED ^ BOT_STAMP_SALT, 1, ANY],
                            kind: FaultKind::Panic,
                        },
                    ]);
                    let mut word_retries = 0u64;
                    let mut stamp_retries = 0u64;
                    for _ in 0..2 {
                        let (ws, ss) = bot.sweep(mode);
                        word_retries += ws.task_retries;
                        stamp_retries += ss.task_retries;
                    }
                    drop(guard);
                    let tag = format!("{mode:?} {residency:?}");
                    assert_eq!(word_retries, 1, "{tag}: one DW-phase commit fault");
                    assert_eq!(stamp_retries, 1, "{tag}: one DTS-phase commit fault");
                    assert_eq!(bot.counts.doc_topic, oracle.counts.doc_topic, "{tag}");
                    assert_eq!(bot.counts.word_topic, oracle.counts.word_topic, "{tag}");
                    assert_eq!(bot.counts.stamp_topic, oracle.counts.stamp_topic, "{tag}");
                    assert_eq!(bot.counts.topic_words, oracle.counts.topic_words, "{tag}");
                    assert_eq!(bot.counts.topic_stamps, oracle.counts.topic_stamps, "{tag}");
                }
            }
        }
    }
}
