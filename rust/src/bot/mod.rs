//! Bag of Timestamps (Masada et al. 2009) and its parallelization
//! (paper §IV-C).
//!
//! BoT extends LDA with a timestamp array `TS_j` of length `L` per
//! document, treated as extra "words" drawn from the shared per-document
//! topic mixture `θ` but emitted from a separate timestamp-per-topic
//! distribution `π` with prior `γ`. Collapsed Gibbs therefore samples:
//!
//! ```text
//! words:      p(k | j,w) ∝ (n_jk + α)(n_kw + β)/(n_k^W  + Wβ)
//! timestamps: p(k | j,s) ∝ (n_jk + α)(n_ks + γ)/(n_k^TS + Sγ)
//! ```
//!
//! with `n_jk` counting *both* word and timestamp assignments (shared θ),
//! and separate totals for the word side (`n_k^W`) and timestamp side
//! (`n_k^TS`).
//!
//! Parallelization (the paper's design): partition `DW` into `P×P` with
//! one plan and `DTS` into `P×P` with an independent plan over the
//! workload matrix `R'`; each of the `P` epochs of a sweep samples one
//! `DW` diagonal, then the corresponding `DTS` diagonal. Both phases are
//! conflict-free within themselves; the shared `n_jk` rows are disjoint
//! per phase because each phase's diagonal uses disjoint document groups.

pub mod counts;
pub mod merged;
pub mod parallel;
pub mod serial;
pub mod timeline;

pub use counts::BotCounts;
pub use parallel::ParallelBot;
pub use serial::{BotHyper, SerialBot};

use crate::corpus::bow::BagOfWords;

/// Word perplexity under BoT (the paper's Table IV metric): Eq. 3–4 with
/// `θ_{k|j} = (n_jk + α)/(n_j + Kα)` where `n_jk` and `n_j` include the
/// timestamp assignments (shared θ), and `φ` from the word side.
pub fn perplexity_words(bow: &BagOfWords, counts: &BotCounts, h: &BotHyper) -> f64 {
    let k = h.k;
    let kalpha = h.alpha as f64 * k as f64;
    let inv_nk: Vec<f64> = counts
        .topic_words
        .iter()
        .map(|&nk| 1.0 / (nk as f64 + h.wbeta as f64))
        .collect();

    let mut ll = 0.0f64;
    let mut theta = vec![0.0f64; k];
    for j in 0..bow.num_docs() {
        let row = counts.doc_row(j);
        let nj: u64 = row.iter().map(|&c| c as u64).sum();
        let inv_nj = 1.0 / (nj as f64 + kalpha);
        for t in 0..k {
            theta[t] = (row[t] as f64 + h.alpha as f64) * inv_nj;
        }
        for e in bow.doc(j) {
            let wrow = counts.word_row(e.word as usize);
            let mut p = 0.0f64;
            for t in 0..k {
                p += theta[t] * (wrow[t] as f64 + h.beta as f64) * inv_nk[t];
            }
            ll += e.count as f64 * p.ln();
        }
    }
    (-ll / bow.num_tokens().max(1) as f64).exp()
}
