//! Minimal property-based testing.
//!
//! A property is a closure over a seeded [`Rng`]; the runner executes it
//! for `cases` independent seeds derived from a base seed and, on panic,
//! re-raises with the failing case seed in the message so the case can be
//! replayed exactly with [`check_one`]. Generators are free functions over
//! `Rng` (sizes, vectors, sparse matrices live next to their modules).
//!
//! This is deliberately simple — no shrinking — but the failing seed plus
//! deterministic generators gives full reproducibility, which is what the
//! invariants in `partition`/`gibbs`/`scheduler` need.

use crate::util::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` for `cases` seeds derived from `base_seed`.
///
/// Panics with the failing derived seed on the first failure.
pub fn check(name: &str, base_seed: u64, cases: usize, prop: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let seed = derive_seed(base_seed, case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property {name:?} failed at case {case} (replay: check_one({name:?}, {seed})): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (used when diagnosing a failure).
pub fn check_one(_name: &str, seed: u64, prop: impl Fn(&mut Rng)) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

fn derive_seed(base: u64, case: u64) -> u64 {
    base.wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(case.wrapping_mul(0xBF58476D1CE4E5B9))
        | 1
}

// ---------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------

/// Size in `[lo, hi]`, log-uniform-ish so small edge sizes are common.
pub fn gen_size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    debug_assert!(lo <= hi);
    if lo == hi {
        return lo;
    }
    // Mix a uniform draw with a bias toward the low end.
    if rng.f64() < 0.3 {
        lo + rng.gen_range((hi - lo).min(4) + 1)
    } else {
        lo + rng.gen_range(hi - lo + 1)
    }
}

/// Random sparse corpus up to `max_d × max_w` with heavy-tailed cell
/// counts — the common input for partition/schedule invariant properties
/// (may be empty: zero-token corpora are legal and must not panic).
pub fn gen_bow(rng: &mut Rng, max_d: usize, max_w: usize) -> crate::corpus::bow::BagOfWords {
    let d = gen_size(rng, 1, max_d);
    let w = gen_size(rng, 1, max_w);
    let nnz = gen_size(rng, 0, (d * w).min(4 * (d + w)));
    let triplets: Vec<(u32, u32, u32)> = (0..nnz)
        .map(|_| {
            (
                rng.gen_range(d) as u32,
                rng.gen_range(w) as u32,
                gen_heavy_tailed(rng, 1, 500)[0],
            )
        })
        .collect();
    crate::corpus::bow::BagOfWords::from_triplets(d, w, triplets)
}

/// Vector of positive weights with a heavy tail (Zipf-like), the shape of
/// real word-frequency workloads.
pub fn gen_heavy_tailed(rng: &mut Rng, len: usize, max: u32) -> Vec<u32> {
    (0..len)
        .map(|_| {
            let u = rng.f64().max(1e-9);
            // Pareto-ish: small values common, occasional huge ones.
            let v = (1.0 / u.powf(0.7)) as u32;
            1 + v.min(max.saturating_sub(1))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 1, 16, |rng| {
            let v = rng.gen_range(10);
            assert!(v < 10);
        });
    }

    #[test]
    fn check_reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 2, 4, |_| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("replay: check_one"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn gen_size_respects_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let v = gen_size(&mut rng, 2, 37);
            assert!((2..=37).contains(&v));
        }
        assert_eq!(gen_size(&mut rng, 5, 5), 5);
    }

    #[test]
    fn heavy_tailed_positive_and_bounded() {
        let mut rng = Rng::new(4);
        let v = gen_heavy_tailed(&mut rng, 5000, 1000);
        assert!(v.iter().all(|&x| x >= 1 && x <= 1000));
        // Heavy tail: max should dwarf the median.
        let mut s = v.clone();
        s.sort_unstable();
        assert!(s[s.len() - 1] as f64 > 10.0 * s[s.len() / 2] as f64);
    }
}
