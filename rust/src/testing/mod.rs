//! In-tree property-based testing harness (offline replacement for
//! `proptest`). See [`prop`].

pub mod prop;
