//! `pplda` — command-line launcher for the partitioned-parallel topic
//! modeling system.
//!
//! ```text
//! pplda stats      [--profile nips|nytimes|mas|tiny] [--scale N] [--uci FILE]
//! pplda partition  [--profile ..] [--scale N] [--procs 1,10,30,60]
//!                  [--algos baseline,A1,A2,A3] [--restarts N] [--seed S]
//! pplda train      [--profile ..] [--scale N] [--procs P] [--algo A3]
//!                  [--topics K] [--iters N] [--eval-every N] [--xla]
//!                  [--mode sequential|threaded|pooled] [--json FILE]
//!                  [--schedule diagonal|packed] [--workers W]
//!                  [--grid-factor G] [--kernel dense|sparse|alias]
//!                  [--balance static|adaptive|steal]
//!                  [--commit barrier|ticketed]
//!                  [--residency in-core|spill] [--memory-budget B]
//!                  [--spill-dir DIR] [--checkpoint-every N]
//!                  [--checkpoint-dir DIR] [--resume PATH]
//!                  [--trace-out FILE] [--snapshot-out FILE]
//! pplda train-bot  [--scale N] [--procs P] [--algo A3] [--topics K]
//!                  [--iters N] [--mode sequential|threaded|pooled]
//!                  [--schedule diagonal|packed] [--workers W]
//!                  [--grid-factor G] [--kernel dense|sparse|alias]
//!                  [--balance static|adaptive|steal] [--timeline]
//!                  [--commit barrier|ticketed]
//!                  [--residency in-core|spill] [--memory-budget B]
//!                  [--spill-dir DIR] [--checkpoint-every N]
//!                  [--checkpoint-dir DIR] [--resume PATH]
//!                  [--trace-out FILE]
//! pplda worker     [--addr HOST:PORT] [--once] [--trace-out FILE]
//!                  [--label NAME]
//! pplda coordinator --dist WORKERS_FILE [train flags]
//!                  [--heartbeat-ms MS] [--liveness-timeout-ms MS]
//!                  [--spec-factor F] [--connect-attempts N]
//!                  [--max-reconnects N]
//! pplda export-snapshot --from CKPT --out FILE [corpus/train flags]
//! pplda serve SNAPSHOT [--addr HOST:PORT] [--serve-workers N]
//!                  [--queue-cap N] [--max-batch N] [--fold-iters N]
//!                  [--min-fold-iters N] [--degrade-at F] [--no-watch]
//!                  [--trace-out FILE]
//! pplda query-bench --addr HOST:PORT [--requests N] [--words N]
//!                  [--deadline-ms MS] [--seed S]
//! pplda analyze-trace FILE [FILE..]
//! pplda artifacts-check
//! ```

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Instant;

use pplda::coordinator::{
    checkpoint, train_bot_traced, train_lda_with_snapshot, Backend, TrainConfig,
};
use pplda::corpus::stats::{table_i, CorpusStats};
use pplda::corpus::synthetic::{self, Profile};
use pplda::corpus::shard::{self, Residency};
use pplda::corpus::{uci, BagOfWords};
use pplda::dist::{self, DistExec, DistOptions};
use pplda::kernel::KernelKind;
use pplda::obs::analyze::{analyze, merge_traces, render};
use pplda::obs::export::{read_trace, write_trace};
use pplda::obs::trace::Tracer;
use pplda::obs::TraceMeta;
use pplda::partition::{self, Algorithm};
#[cfg(feature = "xla")]
use pplda::runtime::executor::Artifacts;
use pplda::scheduler::adaptive::BalanceMode;
use pplda::scheduler::exec::{CommitMode, ExecMode};
use pplda::scheduler::schedule::ScheduleKind;
use pplda::serve::net::{self, Client, NetOptions};
use pplda::serve::server::ServeConfig;
use pplda::serve::snapshot::ModelSnapshot;
use pplda::util::cli::Args;
use pplda::util::interrupt;
use pplda::util::json::Json;
use pplda::util::rng::Rng;
use pplda::util::tsv::{f, Table};

fn main() -> ExitCode {
    let args = Args::from_env();
    match args.positional(0) {
        Some("stats") => cmd_stats(&args),
        Some("partition") => cmd_partition(&args),
        Some("train") => cmd_train(&args),
        Some("train-bot") => cmd_train_bot(&args),
        Some("coordinator") => cmd_train_dist(&args),
        Some("worker") => cmd_worker(&args),
        Some("export-snapshot") => cmd_export_snapshot(&args),
        Some("serve") => cmd_serve(&args),
        Some("query-bench") => cmd_query_bench(&args),
        Some("analyze-trace") => cmd_analyze_trace(&args),
        Some("artifacts-check") => cmd_artifacts_check(),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown subcommand {cmd:?}\n");
            }
            eprint!("{}", USAGE);
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
usage: pplda <stats|partition|train|train-bot|coordinator|worker|export-snapshot|serve|query-bench|analyze-trace|artifacts-check> [flags]

  stats            print Table-I statistics for a corpus
  partition        run partitioning algorithms, print eta per P (Tables II/III)
  train            train (parallel) LDA, print perplexity curve
  train-bot        train (parallel) Bag of Timestamps, print Table-IV row
  coordinator      train LDA across worker processes (== train --dist FILE)
  worker           serve sampling tasks to a coordinator over TCP
  export-snapshot  convert a training checkpoint into a serve snapshot
  serve            serve fold-in queries from a snapshot over TCP (JSON lines)
  query-bench      drive a running server, print latency percentiles
  analyze-trace    reconstruct critical path / idle gaps / eta from trace(s)
  artifacts-check  verify the AOT artifacts load and execute

common flags: --profile nips|nytimes|mas|tiny   --scale N   --seed S
              --uci FILE (real UCI docword file instead of synthetic)

scheduling (train/train-bot): --workers W (default --procs) runs the
sweeps on W executor workers; --schedule packed --grid-factor G
over-decomposes the partition grid to P = G*W and LPT-packs each
diagonal onto the workers (see docs/scheduling.md). The default
--schedule diagonal keeps the legacy P == W coupling.

kernels (train/train-bot): --kernel dense|sparse|alias selects the
per-token sampling kernel (see docs/kernels.md). dense is the O(K)
reference; sparse (SparseLDA s/r/q buckets) and alias (alias tables +
MH correction) amortize to O(k_doc + k_word) per token.

balancing (train/train-bot): --balance static|adaptive|steal picks how
per-epoch load spreads across workers (see docs/scheduling.md).
static packs by token counts; adaptive re-packs each diagonal between
sweeps against measured per-partition wallclock; steal lets idle
workers pull unclaimed tasks from a shared per-epoch queue. All three
train bit-identical counts — only wallclock changes.

committing (train/train-bot): --commit barrier|ticketed picks the
delta-commit protocol (see docs/executor.md). barrier gathers every
epoch's deltas at a full merge barrier; ticketed folds them in ticket
order while later tasks still sample, hiding the gather and the spill
IO behind sampling. Both train bit-identical counts.

out-of-core (train/train-bot): --residency spill streams token blocks
through per-partition spill files, keeping ~two diagonals resident so
corpora larger than RAM train (see docs/out_of_core.md).
--memory-budget B (bytes, k/m/g suffixes; implies spill) bounds
resident token bytes; --spill-dir DIR picks the spill root (default
$PPLDA_SPILL_DIR or the system temp dir). Residency never changes
results — spill is bit-identical to the default in-core.

checkpointing (train/train-bot): --checkpoint-every N commits an
atomic on-disk checkpoint under --checkpoint-dir DIR every N sweeps;
--resume PATH restarts from a checkpoint (a ckpt-N directory, or a
checkpoint dir to scan for the latest) and finishes bit-identically
to the uninterrupted run (see docs/fault_tolerance.md). Requires the
partitioned native backend (P > 1). With a checkpoint cadence set,
SIGINT is graceful: the in-flight sweep finishes, a final checkpoint
is committed, and the run exits 0 with a `checkpointed at sweep N`
line instead of dying mid-write.

serving: `pplda train --snapshot-out FILE` (or `pplda export-snapshot
--from CKPT --out FILE` with the same corpus/train flags as the
original run) writes an immutable PPSNAP1 model snapshot.
`pplda serve SNAPSHOT` serves fold-in queries over a JSON-lines TCP
protocol with bounded admission (--queue-cap), micro-batching
(--max-batch, --serve-workers), per-request deadlines, and graceful
degradation (--fold-iters ramps down to --min-fold-iters past
--degrade-at queue fill). The snapshot file is watched and hot-swapped
atomically on change (disable with --no-watch); a corrupt or torn
publish is rejected and the old model keeps serving. SIGINT or a
shutdown command drains gracefully. `pplda query-bench --addr A`
measures client-side latency percentiles under uniform and skewed word
mixes and emits BENCH_JSON rows (see docs/serving.md).

distributed (train/coordinator/worker): `pplda train --dist FILE` (or
`pplda coordinator --dist FILE`) ships epoch tasks to `pplda worker`
processes listed one host:port per line in FILE, with heartbeats
(--heartbeat-ms), a liveness timeout (--liveness-timeout-ms),
speculative straggler re-execution (--spec-factor), and deterministic
reassignment after a crash — results stay bit-identical to --mode
sequential, faults included (see docs/distributed.md). Workers are
stateless; start them with `pplda worker --addr HOST:PORT` (--once
exits after one coordinator session; --trace-out records a per-node
trace to merge with `analyze-trace FILE FILE..`).

tracing (train/train-bot): --trace-out FILE records per-task spans and
scheduler/IO events into per-worker ring buffers and writes them on
exit — Chrome-trace JSON (Perfetto-loadable) for .json paths, JSONL
otherwise. `pplda analyze-trace FILE` reconstructs the per-sweep
critical path, per-worker idle gaps, steal effectiveness, and
measured eta from the trace (see docs/observability.md). Tracing
never changes results — traced runs are bit-identical to untraced.
";

fn profile(args: &Args) -> Profile {
    let base = match args.get_str("profile").unwrap_or("nips") {
        "nips" => Profile::nips_like(),
        "nytimes" => Profile::nytimes_like(),
        "mas" => Profile::mas_like(),
        "tiny" => Profile::tiny(),
        other => panic!("unknown profile {other:?}"),
    };
    base.scaled(args.get::<usize>("scale", 1))
}

fn load_corpus(args: &Args) -> (String, BagOfWords) {
    if let Some(path) = args.get_str("uci") {
        let bow = uci::load_bow(path).expect("load UCI corpus");
        (path.to_string(), bow)
    } else {
        let p = profile(args);
        let seed = args.get::<u64>("seed", 42);
        (p.name.clone(), synthetic::generate(&p, seed))
    }
}

/// Executor selection: `--mode sequential|threaded|pooled` (preferred),
/// with `--threads` kept as an alias for `--mode threaded`.
fn exec_mode(args: &Args) -> ExecMode {
    if let Some(m) = args.get_str("mode") {
        ExecMode::parse(m)
            .unwrap_or_else(|| panic!("unknown exec mode {m:?} (sequential|threaded|pooled)"))
    } else if args.has("threads") {
        ExecMode::Threaded
    } else {
        ExecMode::Sequential
    }
}

/// Schedule selection: `--schedule diagonal|packed`, `--grid-factor G`
/// (implies packed when > 1), `--workers W` (default: `--procs`). Returns
/// the kind and the worker count; the partition grid is
/// `kind.grid(workers)`.
fn schedule_of(args: &Args, default_workers: usize) -> (ScheduleKind, usize) {
    let g = args.get::<usize>("grid-factor", 1);
    assert!(g >= 1, "--grid-factor must be >= 1");
    let name = args
        .get_str("schedule")
        .unwrap_or(if g > 1 { "packed" } else { "diagonal" });
    let kind = ScheduleKind::parse(name, g)
        .unwrap_or_else(|| panic!("unknown schedule {name:?} (diagonal|packed)"));
    if kind == ScheduleKind::Diagonal && g > 1 {
        panic!("--grid-factor {g} requires --schedule packed");
    }
    let workers = args.get::<usize>("workers", default_workers);
    assert!(workers >= 1, "--workers must be >= 1");
    (kind, workers)
}

/// Kernel selection: `--kernel dense|sparse|alias` (default dense).
fn kernel_of(args: &Args) -> KernelKind {
    match args.get_str("kernel") {
        Some(s) => KernelKind::parse(s)
            .unwrap_or_else(|| panic!("unknown kernel {s:?} (dense|sparse|alias)")),
        None => KernelKind::Dense,
    }
}

/// Residency selection: `--residency in-core|spill` plus
/// `--memory-budget BYTES` (k/m/g suffixes; a budget alone implies
/// spill) and `--spill-dir DIR` (exported as `PPLDA_SPILL_DIR` for the
/// trainers' temp stores).
fn residency_of(args: &Args) -> Residency {
    if let Some(dir) = args.get_str("spill-dir") {
        std::env::set_var("PPLDA_SPILL_DIR", dir);
    }
    let budget = match args.get_str("memory-budget") {
        Some(s) => shard::parse_bytes(s).unwrap_or_else(|| {
            panic!("--memory-budget {s:?}: expected bytes with an optional k/m/g suffix")
        }),
        None => 0,
    };
    match args.get_str("residency") {
        Some(s) => {
            let r = Residency::parse(s, budget)
                .unwrap_or_else(|| panic!("unknown residency {s:?} (in-core|spill)"));
            if budget > 0 && r == Residency::InCore {
                // A stale --memory-budget must not silently become an
                // unbounded run.
                panic!("--memory-budget only applies to --residency spill");
            }
            r
        }
        None if budget > 0 => Residency::Spill { budget_bytes: budget },
        None => Residency::InCore,
    }
}

/// Balance selection: `--balance static|adaptive|steal` (default static).
fn balance_of(args: &Args) -> BalanceMode {
    match args.get_str("balance") {
        Some(s) => BalanceMode::parse(s)
            .unwrap_or_else(|| panic!("unknown balance mode {s:?} (static|adaptive|steal)")),
        None => BalanceMode::Static,
    }
}

/// Commit-protocol selection: `--commit barrier|ticketed` (default
/// barrier).
fn commit_of(args: &Args) -> CommitMode {
    match args.get_str("commit") {
        Some(s) => CommitMode::parse(s)
            .unwrap_or_else(|| panic!("unknown commit mode {s:?} (barrier|ticketed)")),
        None => CommitMode::Barrier,
    }
}

/// Checkpoint flags: `--checkpoint-every N` (commits under
/// `--checkpoint-dir DIR`) and `--resume PATH`. Both halves of the
/// periodic pair are required together so a stale flag never silently
/// disables checkpointing.
fn checkpoint_of(args: &Args) -> (usize, Option<PathBuf>, Option<PathBuf>) {
    let every = args.get::<usize>("checkpoint-every", 0);
    let dir = args.get_str("checkpoint-dir").map(PathBuf::from);
    let resume = args.get_str("resume").map(PathBuf::from);
    if every > 0 && dir.is_none() {
        panic!("--checkpoint-every requires --checkpoint-dir DIR");
    }
    if every == 0 && dir.is_some() {
        panic!("--checkpoint-dir requires --checkpoint-every N");
    }
    (every, dir, resume)
}

/// Tracing selection: `--trace-out FILE` attaches a [`Tracer`] sized
/// for `workers` lanes; the trace is written to FILE after training
/// (Chrome-trace JSON for `.json` paths, JSONL otherwise).
fn tracer_of(args: &Args, workers: usize) -> Option<(PathBuf, Arc<Tracer>)> {
    args.get_str("trace-out")
        .map(|path| (PathBuf::from(path), Arc::new(Tracer::new(workers))))
}

/// Flush a recorded trace to disk and report where it went.
fn write_trace_out(path: &Path, tracer: &Tracer, label: String) {
    let events = tracer.take();
    let meta = TraceMeta {
        workers: tracer.workers(),
        dropped: tracer.dropped(),
        label,
    };
    write_trace(path, &events, &meta).expect("write trace");
    println!(
        "wrote {} ({} events, {} dropped)",
        path.display(),
        events.len(),
        meta.dropped
    );
}

fn algo_of(name: &str, restarts: usize) -> Algorithm {
    match name {
        "baseline" => Algorithm::Baseline { restarts },
        "A1" | "a1" => Algorithm::A1,
        "A2" | "a2" => Algorithm::A2,
        "A3" | "a3" => Algorithm::A3 { restarts },
        other => panic!("unknown algorithm {other:?}"),
    }
}

fn cmd_stats(args: &Args) -> ExitCode {
    let (name, bow) = load_corpus(args);
    let stats = CorpusStats::of(&name, &bow);
    print!("{}", table_i(&[stats]).to_aligned());
    ExitCode::SUCCESS
}

fn cmd_partition(args: &Args) -> ExitCode {
    let (name, bow) = load_corpus(args);
    let procs = args.get_list::<usize>("procs", &[1, 10, 30, 60]);
    let restarts = args.get::<usize>("restarts", 100);
    let seed = args.get::<u64>("seed", 42);
    let algos: Vec<String> = args.get_list::<String>("algos", &[]);
    let algos = if algos.is_empty() {
        ["baseline", "A1", "A2", "A3"]
            .map(String::from)
            .to_vec()
    } else {
        algos
    };

    println!(
        "corpus {name}: D={} W={} N={}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens()
    );
    let mut header = vec!["P".to_string()];
    header.extend(algos.iter().cloned());
    let mut table = Table::new(header);
    for &p in &procs {
        let mut row = vec![p.to_string()];
        for a in &algos {
            let plan = partition::partition(&bow, p, algo_of(a, restarts), seed);
            row.push(f(plan.eta, 4));
        }
        table.row(row);
    }
    print!("{}", table.to_aligned());
    ExitCode::SUCCESS
}

fn cmd_train(args: &Args) -> ExitCode {
    if args.get_str("dist").is_some() {
        return cmd_train_dist(args);
    }
    let (name, bow) = load_corpus(args);
    let procs = args.get::<usize>("procs", 8);
    let (kind, workers) = schedule_of(args, procs);
    let grid = kind.grid(workers);
    let restarts = args.get::<usize>("restarts", 20);
    let algo = algo_of(args.get_str("algo").unwrap_or("A3"), restarts);
    let (checkpoint_every, checkpoint_dir, resume) = checkpoint_of(args);
    let cfg = TrainConfig {
        topics: args.get::<usize>("topics", 64),
        iters: args.get::<usize>("iters", 100),
        eval_every: args.get::<usize>("eval-every", 10),
        seed: args.get::<u64>("seed", 42),
        backend: if args.has("xla") {
            Backend::Xla
        } else {
            Backend::Native
        },
        mode: exec_mode(args),
        workers,
        schedule: kind,
        kernel: kernel_of(args),
        balance: balance_of(args),
        commit: commit_of(args),
        residency: residency_of(args),
        checkpoint_every,
        ..Default::default()
    };

    let plan = partition::partition(&bow, grid, algo, cfg.seed);
    println!(
        "corpus {name}: D={} W={} N={} | plan {} P={} eta={:.4} | schedule {} workers={} \
         kernel={} balance={} commit={} residency={}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens(),
        plan.algorithm,
        plan.p,
        plan.eta,
        kind.label(),
        workers,
        cfg.kernel.name(),
        cfg.balance.name(),
        cfg.commit.name(),
        cfg.residency.label(),
    );
    if cfg.checkpoint_every > 0 {
        // SIGINT finishes the in-flight sweep and checkpoints instead
        // of killing the process mid-write.
        interrupt::install();
    }
    let snapshot_out = args.get_str("snapshot-out").map(PathBuf::from);
    let trace = tracer_of(args, workers);
    let report = train_lda_with_snapshot(
        &bow,
        &plan,
        &cfg,
        checkpoint_dir.as_deref(),
        resume.as_deref(),
        trace.as_ref().map(|(_, tr)| tr),
        snapshot_out.as_deref(),
    );
    if let Some((path, tr)) = &trace {
        write_trace_out(path, tr, format!("pplda train --profile {name}"));
    }
    if let Some(path) = &snapshot_out {
        println!("wrote snapshot {}", path.display());
    }
    println!(
        "schedule_eta={:.4} measured_eta={:.4} speedup≈{:.2} (vs {} workers)",
        report.schedule_eta, report.measured_eta, report.speedup_model, report.workers
    );
    if !report.phases.is_empty() {
        println!("phases: {}", report.phase_summary());
    }
    if report.task_retries > 0 || report.io_retries > 0 {
        println!(
            "fault recovery: task_retries={} io_retries={}",
            report.task_retries, report.io_retries
        );
    }
    print!("{}", report.curve_table().to_aligned());
    println!(
        "final perplexity {:.4} | {:.1}s | {} tokens/s",
        report.final_perplexity,
        report.train_secs,
        pplda::util::human_rate(report.tokens_per_sec)
    );
    if let Some(path) = args.get_str("json") {
        std::fs::write(path, report.to_json().to_string_pretty()).expect("write json");
        println!("wrote {path}");
    }
    if let Some(it) = report.interrupted_at {
        println!("checkpointed at sweep {it}");
    }
    ExitCode::SUCCESS
}

/// Distributed LDA training: the `coordinator` subcommand, also reached
/// through `train --dist FILE`. Same corpus/plan/train flags as `train`;
/// epoch execution goes to the workers listed in FILE.
fn cmd_train_dist(args: &Args) -> ExitCode {
    let Some(dist_file) = args.get_str("dist") else {
        eprintln!("coordinator: --dist WORKERS_FILE is required (one host:port per line)");
        return ExitCode::FAILURE;
    };
    let addrs = match dist::parse_workers_file(Path::new(dist_file)) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("coordinator: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (name, bow) = load_corpus(args);
    let procs = args.get::<usize>("procs", 8);
    let (kind, workers) = schedule_of(args, procs);
    let grid = kind.grid(workers);
    let restarts = args.get::<usize>("restarts", 20);
    let algo = algo_of(args.get_str("algo").unwrap_or("A3"), restarts);
    let (checkpoint_every, checkpoint_dir, _resume) = checkpoint_of(args);
    let cfg = TrainConfig {
        topics: args.get::<usize>("topics", 64),
        iters: args.get::<usize>("iters", 100),
        eval_every: args.get::<usize>("eval-every", 10),
        seed: args.get::<u64>("seed", 42),
        workers,
        schedule: kind,
        kernel: kernel_of(args),
        balance: balance_of(args),
        commit: commit_of(args),
        checkpoint_every,
        ..Default::default()
    };
    let opts = DistOptions {
        heartbeat_ms: args.get::<u64>("heartbeat-ms", 500),
        liveness_timeout_ms: args.get::<u64>("liveness-timeout-ms", 2000),
        spec_factor: args.get::<f64>("spec-factor", 3.0),
        connect_attempts: args.get::<u32>("connect-attempts", 10),
        max_reconnects: args.get::<u32>("max-reconnects", 3),
    };
    let plan = partition::partition(&bow, grid, algo, cfg.seed);
    println!(
        "corpus {name}: D={} W={} N={} | plan {} P={} eta={:.4} | dist nodes={} \
         schedule {} workers={} kernel={} balance={} commit={}",
        bow.num_docs(),
        bow.num_words(),
        bow.num_tokens(),
        plan.algorithm,
        plan.p,
        plan.eta,
        addrs.len(),
        kind.label(),
        workers,
        cfg.kernel.name(),
        cfg.balance.name(),
        cfg.commit.name(),
    );
    interrupt::install();
    let mut exec = match DistExec::connect(&addrs, opts) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("coordinator: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!("connected to {} worker(s)", exec.live_nodes());
    // Trace lanes: one per node (remote task spans land on the owning
    // node's lane), never fewer than the schedule's worker count.
    let trace = tracer_of(args, workers.max(addrs.len()));
    let report = dist::train_lda_dist(
        &bow,
        &plan,
        &cfg,
        &mut exec,
        trace.as_ref().map(|(_, tr)| tr),
        checkpoint_dir.as_deref(),
    );
    exec.shutdown();
    if let Some((path, tr)) = &trace {
        write_trace_out(path, tr, format!("pplda coordinator --profile {name}"));
    }
    let mut curve = Table::new(vec!["sweep".into(), "perplexity".into()]);
    for (s, p) in &report.curve {
        curve.row(vec![s.to_string(), f(*p, 4)]);
    }
    print!("{}", curve.to_aligned());
    if report.reassigns > 0 || report.speculations > 0 || report.local_fallbacks > 0 {
        println!(
            "fault recovery: reassigns={} speculations={} local_fallbacks={}",
            report.reassigns, report.speculations, report.local_fallbacks
        );
    }
    if let Some(path) = &report.checkpoint {
        println!("checkpointed at sweep {} -> {}", report.sweeps, path.display());
    }
    println!(
        "final perplexity {:.4} | {:.1}s | {} tokens/s",
        report.final_perplexity,
        report.train_secs,
        pplda::util::human_rate(report.tokens_per_sec)
    );
    if let Some(path) = args.get_str("json") {
        let mut j = Json::obj();
        j.set("final_perplexity", report.final_perplexity);
        j.set("sweeps", report.sweeps as u64);
        j.set("nodes", exec.nodes() as u64);
        j.set("reassigns", report.reassigns);
        j.set("speculations", report.speculations);
        j.set("local_fallbacks", report.local_fallbacks);
        j.set("train_secs", report.train_secs);
        std::fs::write(path, j.to_string()).expect("write json");
        println!("wrote {path}");
    }
    ExitCode::SUCCESS
}

/// The worker process: serve sampling tasks to a coordinator. Stateless
/// between tasks; SIGINT/SIGTERM exit the accept loop cleanly.
fn cmd_worker(args: &Args) -> ExitCode {
    let addr = args.get_str("addr").unwrap_or("127.0.0.1:7700");
    interrupt::install();
    // Chaos hook for the distributed smoke test: `--chaos-kill S,P`
    // arms a worker-side panic at sweep S, partition P (requires a
    // `--features failpoints` build; rejected otherwise so a stale
    // flag never silently no-ops).
    if let Some(spec) = args.get_str("chaos-kill") {
        match install_chaos_kill(spec) {
            Ok(()) => println!("worker: chaos-kill armed at {spec}"),
            Err(e) => {
                eprintln!("worker: --chaos-kill {spec}: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    let opts = dist::WorkerOptions {
        once: args.has("once"),
        trace_out: args.get_str("trace-out").map(PathBuf::from),
        label: args.get_str("label").map(String::from),
    };
    match dist::serve_worker(addr, &opts) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("worker: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(feature = "failpoints")]
fn install_chaos_kill(spec: &str) -> Result<(), String> {
    use pplda::util::fault::{self, Fault, FaultKind};
    let (sweep, part) = spec
        .split_once(',')
        .ok_or_else(|| "expected SWEEP,PARTITION".to_string())?;
    let sweep: u64 = sweep.trim().parse().map_err(|_| format!("bad sweep {sweep:?}"))?;
    let part: u64 = part.trim().parse().map_err(|_| format!("bad partition {part:?}"))?;
    let guard = fault::install(vec![Fault {
        site: fault::sites::DIST_WORKER,
        key: [fault::ANY, sweep, part],
        kind: FaultKind::Panic,
    }]);
    // The plan must stay armed for the process lifetime — this is a
    // one-shot chaos process, not a test with cleanup.
    std::mem::forget(guard);
    Ok(())
}

#[cfg(not(feature = "failpoints"))]
fn install_chaos_kill(_spec: &str) -> Result<(), String> {
    Err("this build has no failpoints; rebuild with --features failpoints".into())
}

fn cmd_train_bot(args: &Args) -> ExitCode {
    let p_profile = {
        let mut pr = Profile::mas_like().scaled(args.get::<usize>("scale", 50));
        if args.get_str("profile") == Some("tiny") {
            pr = Profile::tiny();
            pr.time = Some(synthetic::TimeProfile {
                first_year: 2000,
                last_year: 2009,
                growth: 0.1,
                stamps_per_doc: 4,
            });
        }
        pr
    };
    let seed = args.get::<u64>("seed", 42);
    let tc = synthetic::generate_timestamped(&p_profile, seed);
    let procs = args.get::<usize>("procs", 10);
    let (kind, workers) = schedule_of(args, procs);
    let p = kind.grid(workers);
    let restarts = args.get::<usize>("restarts", 20);
    let algo = algo_of(args.get_str("algo").unwrap_or("A3"), restarts);
    let (checkpoint_every, checkpoint_dir, resume) = checkpoint_of(args);
    let cfg = TrainConfig {
        topics: args.get::<usize>("topics", 64),
        iters: args.get::<usize>("iters", 50),
        seed,
        mode: exec_mode(args),
        workers,
        schedule: kind,
        kernel: kernel_of(args),
        balance: balance_of(args),
        commit: commit_of(args),
        residency: residency_of(args),
        checkpoint_every,
        ..Default::default()
    };

    println!(
        "corpus {}: D={} W={} N={} stamps={} ({} ts tokens)",
        p_profile.name,
        tc.bow.num_docs(),
        tc.bow.num_words(),
        tc.bow.num_tokens(),
        tc.num_stamps,
        tc.dts.num_tokens()
    );
    if cfg.checkpoint_every > 0 {
        interrupt::install();
    }
    let trace = tracer_of(args, workers);
    let report = train_bot_traced(
        &tc,
        p,
        algo,
        &cfg,
        checkpoint_dir.as_deref(),
        resume.as_deref(),
        trace.as_ref().map(|(_, tr)| tr),
    );
    if let Some((path, tr)) = &trace {
        write_trace_out(path, tr, format!("pplda train-bot --profile {}", p_profile.name));
    }
    println!(
        "P={} workers={} schedule={} kernel={} balance={} commit={} residency={} \
         perplexity={:.4} eta_dw={:.4} eta_dts={:.4} measured_eta_dw={:.4} \
         measured_eta_dts={:.4} speedup≈{:.2} ({:.1}s)",
        report.p,
        report.workers,
        report.schedule,
        report.kernel,
        report.balance,
        report.commit,
        report.residency,
        report.final_perplexity,
        report.eta_dw,
        report.eta_dts,
        report.measured_eta_dw,
        report.measured_eta_dts,
        report.speedup_model,
        report.train_secs
    );
    if args.has("timeline") {
        let first = p_profile.time.as_ref().map(|t| t.first_year).unwrap_or(0);
        print!(
            "{}",
            pplda::bot::timeline::trend_table(&report.timelines, first, 5).to_aligned()
        );
    }
    if let Some(it) = report.interrupted_at {
        println!("checkpointed at sweep {it}");
    }
    ExitCode::SUCCESS
}

/// Convert a training checkpoint into a serve snapshot. The corpus and
/// train flags must match the run that wrote the checkpoint (the
/// checkpoint manifest validates them), exactly as `--resume` does.
fn cmd_export_snapshot(args: &Args) -> ExitCode {
    let Some(from) = args.get_str("from") else {
        eprintln!("usage: pplda export-snapshot --from CKPT --out FILE [corpus/train flags]");
        return ExitCode::FAILURE;
    };
    let Some(out) = args.get_str("out") else {
        eprintln!("usage: pplda export-snapshot --from CKPT --out FILE [corpus/train flags]");
        return ExitCode::FAILURE;
    };
    let (name, bow) = load_corpus(args);
    let procs = args.get::<usize>("procs", 8);
    let (kind, workers) = schedule_of(args, procs);
    let grid = kind.grid(workers);
    let restarts = args.get::<usize>("restarts", 20);
    let algo = algo_of(args.get_str("algo").unwrap_or("A3"), restarts);
    let cfg = TrainConfig {
        topics: args.get::<usize>("topics", 64),
        iters: args.get::<usize>("iters", 100),
        seed: args.get::<u64>("seed", 42),
        workers,
        schedule: kind,
        ..Default::default()
    };
    let plan = partition::partition(&bow, grid, algo, cfg.seed);
    let (lda, sweeps) = checkpoint::resume_lda(&bow, &plan, &cfg, Path::new(from))
        .unwrap_or_else(|e| panic!("resume failed: {e}"));
    let snap = ModelSnapshot::from_counts(&lda.counts, cfg.alpha, cfg.beta, cfg.seed);
    snap.write(Path::new(out))
        .unwrap_or_else(|e| panic!("snapshot write failed: {e}"));
    println!(
        "exported snapshot {out} (corpus {name}, K={} V={} seed={}, sweep {sweeps})",
        snap.k, snap.v, snap.seed
    );
    ExitCode::SUCCESS
}

/// Serve fold-in queries from a snapshot over the JSON-lines TCP
/// protocol until SIGINT or a `shutdown` command, then drain.
fn cmd_serve(args: &Args) -> ExitCode {
    let Some(snap_path) = args.positional(1) else {
        eprintln!("usage: pplda serve SNAPSHOT [--addr HOST:PORT] [flags]");
        return ExitCode::FAILURE;
    };
    interrupt::install();
    let cfg = ServeConfig {
        workers: args.get::<usize>("serve-workers", 2),
        queue_capacity: args.get::<usize>("queue-cap", 256),
        max_batch: args.get::<usize>("max-batch", 8),
        fold_iters: args.get::<usize>("fold-iters", 10),
        min_fold_iters: args.get::<usize>("min-fold-iters", 2),
        degrade_at: args.get::<f64>("degrade-at", 0.5),
    };
    let opts = NetOptions {
        addr: args.get_str("addr").unwrap_or("127.0.0.1:0").to_string(),
        watch: !args.has("no-watch"),
    };
    let trace = tracer_of(args, cfg.workers);
    match net::serve(
        Path::new(snap_path),
        &opts,
        cfg,
        trace.as_ref().map(|(_, tr)| Arc::clone(tr)),
    ) {
        Ok(()) => {
            if let Some((path, tr)) = &trace {
                write_trace_out(path, tr, "pplda serve".to_string());
            }
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("serve: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Drive a running server with uniform and skewed word mixes; print
/// client-side latency percentiles and emit one BENCH_JSON row per mix.
fn cmd_query_bench(args: &Args) -> ExitCode {
    let Some(addr) = args.get_str("addr") else {
        eprintln!("usage: pplda query-bench --addr HOST:PORT [--requests N] [--words N]");
        return ExitCode::FAILURE;
    };
    let addr: std::net::SocketAddr = addr.parse().expect("--addr must be HOST:PORT");
    let requests = args.get::<usize>("requests", 200);
    let words_per = args.get::<usize>("words", 16);
    let deadline_ms = args.get::<u64>("deadline-ms", 0);
    let deadline = (deadline_ms > 0).then_some(deadline_ms);
    let seed = args.get::<u64>("seed", 42);

    let mut client = Client::connect(&addr).expect("connect to server");
    let info = client.info().expect("info command");
    let v = info.get("v").and_then(Json::as_u64).expect("server reports V") as usize;
    assert!(v > 0, "server vocabulary is empty");

    for (mix, skewed) in [("uniform", false), ("skewed", true)] {
        let mut rng = Rng::stream(seed, if skewed { 1 } else { 0 });
        let mut lat_ms: Vec<f64> = Vec::with_capacity(requests);
        let (mut ok, mut degraded, mut errors) = (0u64, 0u64, 0u64);
        let started = Instant::now();
        for i in 0..requests {
            let words: Vec<u32> = (0..words_per)
                .map(|_| {
                    if skewed {
                        // Head-heavy mix: cubing the uniform draw piles
                        // the mass onto low word ids (Zipf-ish).
                        let u = rng.f64();
                        ((u * u * u * v as f64) as usize).min(v - 1) as u32
                    } else {
                        rng.gen_range(v) as u32
                    }
                })
                .collect();
            let t = Instant::now();
            let reply = client.query(i as u64, &words, deadline).expect("query");
            let ms = t.elapsed().as_secs_f64() * 1e3;
            if reply.get("ok").and_then(Json::as_bool) == Some(true) {
                ok += 1;
                lat_ms.push(ms);
                if reply.get("degraded").and_then(Json::as_bool) == Some(true) {
                    degraded += 1;
                }
            } else {
                errors += 1;
            }
        }
        let elapsed = started.elapsed().as_secs_f64().max(1e-9);
        lat_ms.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| -> f64 {
            if lat_ms.is_empty() {
                return 0.0;
            }
            let idx = ((lat_ms.len() as f64 - 1.0) * p).round() as usize;
            lat_ms[idx]
        };
        let (p50, p99) = (pct(0.50), pct(0.99));
        println!(
            "query-bench {mix}: {ok}/{requests} ok ({:.1} qps) | p50 {p50:.2}ms p99 {p99:.2}ms \
             | degraded {degraded} errors {errors}",
            ok as f64 / elapsed
        );
        let mut row = Json::obj();
        row.set("bench", "query_bench")
            .set("mix", mix)
            .set("requests", requests)
            .set("ok", ok)
            .set("degraded", degraded)
            .set("errors", errors)
            .set("qps", ok as f64 / elapsed)
            .set("p50_ms", p50)
            .set("p99_ms", p99);
        println!("BENCH_JSON {}", row.to_string());
    }
    let _ = client.stats();
    ExitCode::SUCCESS
}

/// Analyze one trace, or merge several (a distributed run's coordinator
/// trace plus per-worker traces — coordinator first, see
/// [`merge_traces`]) into node-banded lanes and analyze the union.
fn cmd_analyze_trace(args: &Args) -> ExitCode {
    let mut paths = Vec::new();
    let mut i = 1;
    while let Some(p) = args.positional(i) {
        paths.push(p.to_string());
        i += 1;
    }
    if paths.is_empty() {
        eprintln!("usage: pplda analyze-trace FILE [FILE..]");
        return ExitCode::FAILURE;
    }
    let mut traces = Vec::with_capacity(paths.len());
    for path in &paths {
        match read_trace(Path::new(path)) {
            Ok(v) => traces.push(v),
            Err(e) => {
                eprintln!("analyze-trace: {path}: {e}");
                return ExitCode::FAILURE;
            }
        }
    }
    let (events, meta) = if traces.len() == 1 {
        traces.pop().expect("one trace")
    } else {
        merge_traces(&traces)
    };
    if !meta.label.is_empty() {
        println!("run: {}", meta.label);
    }
    match analyze(&events, &meta) {
        Ok(an) => {
            print!("{}", render(&an));
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("analyze-trace: {}: invalid trace: {e}", paths.join(" "));
            ExitCode::FAILURE
        }
    }
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts_check() -> ExitCode {
    eprintln!(
        "pplda was built without the `xla` feature; \
         rebuild with `--features xla` to use the PJRT artifacts"
    );
    ExitCode::FAILURE
}

#[cfg(feature = "xla")]
fn cmd_artifacts_check() -> ExitCode {
    let dir = Artifacts::default_dir();
    if !Artifacts::available(&dir) {
        eprintln!("no artifacts at {dir:?}; run `make artifacts`");
        return ExitCode::FAILURE;
    }
    let arts = Artifacts::discover(&dir).expect("parse manifest");
    for (b, k) in arts.variants("sampler") {
        let exe = arts.sampler(b, k).expect("compile sampler");
        let njk = vec![1.0f32; b * k];
        let nkw = vec![1.0f32; b * k];
        let nk = vec![k as f32; k];
        let unif = vec![0.5f32; b * k];
        let z = exe
            .run(&njk, &nkw, &nk, &unif, [0.5, 0.1, 0.5 * k as f32, 0.1 * 100.0])
            .expect("run sampler");
        assert_eq!(z.len(), b);
        println!("sampler_{b}x{k}: ok");
    }
    for (b, k) in arts.variants("loglik") {
        let exe = arts.loglik(b, k).expect("compile loglik");
        let njk = vec![1.0f32; b * k];
        let nj = vec![k as f32; b];
        let nkw = vec![1.0f32; b * k];
        let nk = vec![k as f32; k];
        let (sum, ll) = exe
            .run(&njk, &nj, &nkw, &nk, [0.5, 0.1, 0.5 * k as f32, 0.1 * 100.0])
            .expect("run loglik");
        assert_eq!(ll.len(), b);
        assert!(sum.is_finite());
        println!("loglik_{b}x{k}: ok (sum={sum:.2})");
    }
    println!("all artifacts ok");
    ExitCode::SUCCESS
}
